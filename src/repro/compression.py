"""Codec negotiation for on-disk blobs (forest snapshots, checkpoint shards).

``zstandard`` is an optional wheel: when present it is preferred (better
ratio and speed), otherwise stdlib ``zlib`` is used. Every blob written
through :func:`compress` carries a one-byte codec tag so a reader on a
machine *without* zstd can still refuse a zstd blob with a clear error
instead of garbage, and vice versa. Legacy tag-less zstd blobs (written
before the flag byte existed) are recognized by the zstd frame magic.
"""
from __future__ import annotations

import zlib

try:
    import zstandard as _zstd
except ImportError:          # optional dependency
    _zstd = None

TAG_ZSTD = b"\x01"
TAG_ZLIB = b"\x02"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"

HAVE_ZSTD = _zstd is not None


def compress(data: bytes, level: int = 3) -> bytes:
    """Compress with the best available codec; output is tagged."""
    if _zstd is not None:
        return TAG_ZSTD + _zstd.ZstdCompressor(level=level).compress(data)
    return TAG_ZLIB + zlib.compress(data, level)


def decompress(blob: bytes) -> bytes:
    if blob[:1] == TAG_ZLIB:
        return zlib.decompress(blob[1:])
    if blob[:1] == TAG_ZSTD:
        body = blob[1:]
    elif blob[:4] == _ZSTD_MAGIC:   # legacy: untagged zstd frame
        body = blob
    else:
        raise ValueError("unrecognized compression tag in blob")
    if _zstd is None:
        raise ModuleNotFoundError(
            "blob was written with zstandard, which is not installed; "
            "install the 'zstandard' wheel to read it")
    return _zstd.ZstdDecompressor().decompress(body)
