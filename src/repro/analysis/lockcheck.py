"""Dynamic lock-order checking for the serve stack.

The engine serve thread, the maintenance plane's background worker, and
the residency manager share two RLocks (``MaintenancePlane.lock``,
``ResidencyManager.lock``) plus small leaf locks inside the observability
layer. Nothing enforces an acquisition order — a refactor that makes the
residency manager call back into the plane while the plane's worker holds
its own lock and is evicting a tenant would deadlock only under loaded
concurrency, which tests rarely produce. This module makes the order an
asserted property instead:

  * :class:`CheckedLock` — a Lock/RLock wrapper that reports every
    acquire/release to a shared :class:`LockOrderGraph`.
  * :class:`LockOrderGraph` — records the union acquisition graph across
    threads (edge ``A -> B`` = some thread acquired B while holding A;
    re-entrant re-acquisition adds no edge) and finds cycles — the static
    precondition of an ABBA deadlock, detectable even when the schedule
    happened not to interleave fatally.
  * :class:`BlockingCallWatch` — patches known blocking calls
    (``os.fsync``, ``time.sleep``) to record when they run with
    instrumented locks held. fsync-under-lock is sometimes *required*
    (demotion must persist state before freeing the device cache), so the
    harness asserts the observed set against an explicit allowlist rather
    than forbidding it outright.
  * :func:`check_schedule` — replays a simulated acquisition schedule
    (no real locks, no real threads) through a fresh graph; this is what
    the property test drives with random planted-cycle schedules.
  * :func:`instrument` — swaps a component's ``lock`` attribute for a
    CheckedLock, so the pytest harness can wire the real engine/plane/
    residency stack into one graph without code changes.

Run via tests/test_lockcheck.py: concurrent background-maintenance +
residency-eviction + engine traffic, then ``graph.assert_acyclic()``.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["CheckedLock", "LockOrderGraph", "LockOrderViolation",
           "BlockingCallWatch", "check_schedule", "instrument"]


class LockOrderViolation(AssertionError):
    """Raised by :meth:`LockOrderGraph.assert_acyclic` with every cycle and
    the stack-free edge provenance (who acquired what while holding what)."""


class LockOrderGraph:
    """Union lock-acquisition graph across threads.

    ``thread=`` on the ``on_*`` hooks substitutes a simulated thread id so
    schedules can be replayed without real concurrency (property tests);
    real CheckedLocks pass the calling thread's ident implicitly.
    """

    def __init__(self) -> None:
        self._mu = threading.Lock()
        # (held, acquired) -> times observed
        self.edges: Dict[Tuple[str, str], int] = {}
        self._held: Dict[object, List[str]] = {}
        # (locks held at call time, blocking call name)
        self.blocking_calls: List[Tuple[Tuple[str, ...], str]] = []

    # -- hooks -------------------------------------------------------------
    def on_acquire(self, name: str, *, thread: object = None) -> None:
        t = thread if thread is not None else threading.get_ident()
        with self._mu:
            held = self._held.setdefault(t, [])
            if name not in held:            # re-entrant acquire: no new edge
                for h in dict.fromkeys(held):
                    self.edges[(h, name)] = self.edges.get((h, name), 0) + 1
            held.append(name)

    def on_release(self, name: str, *, thread: object = None) -> None:
        t = thread if thread is not None else threading.get_ident()
        with self._mu:
            held = self._held.get(t, [])
            for i in range(len(held) - 1, -1, -1):
                if held[i] == name:
                    del held[i]
                    break

    def held_by(self, thread: object = None) -> Tuple[str, ...]:
        t = thread if thread is not None else threading.get_ident()
        with self._mu:
            return tuple(dict.fromkeys(self._held.get(t, ())))

    def note_blocking(self, what: str) -> None:
        held = self.held_by()
        if held:
            with self._mu:
                self.blocking_calls.append((held, what))

    # -- analysis ----------------------------------------------------------
    def adjacency(self) -> Dict[str, List[str]]:
        adj: Dict[str, List[str]] = {}
        with self._mu:
            edges = list(self.edges)
        for a, b in edges:
            adj.setdefault(a, []).append(b)
            adj.setdefault(b, [])
        for k in adj:
            adj[k].sort()
        return adj

    def cycles(self) -> List[List[str]]:
        """Every elementary cycle reachable by DFS back edges (deterministic
        order). Empty list = a consistent global acquisition order exists."""
        adj = self.adjacency()
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in adj}
        out: List[List[str]] = []
        seen_keys = set()
        path: List[str] = []

        def visit(n: str) -> None:
            color[n] = GRAY
            path.append(n)
            for m in adj[n]:
                if color[m] == GRAY:
                    cyc = path[path.index(m):] + [m]
                    # canonicalize (rotation-invariant) to dedup
                    body = cyc[:-1]
                    i = body.index(min(body))
                    key = tuple(body[i:] + body[:i])
                    if key not in seen_keys:
                        seen_keys.add(key)
                        out.append(list(key) + [key[0]])
                elif color[m] == WHITE:
                    visit(m)
            path.pop()
            color[n] = BLACK

        for n in sorted(adj):
            if color[n] == WHITE:
                visit(n)
        return out

    def assert_acyclic(self) -> None:
        cyc = self.cycles()
        if cyc:
            lines = [" -> ".join(c) for c in cyc]
            raise LockOrderViolation(
                "lock-acquisition graph has cycle(s) — ABBA deadlock "
                "precondition:\n  " + "\n  ".join(lines))


class CheckedLock:
    """Drop-in Lock/RLock replacement that reports to a LockOrderGraph."""

    def __init__(self, name: str, graph: LockOrderGraph, *,
                 reentrant: bool = True):
        self.name = name
        self.graph = graph
        self._inner = threading.RLock() if reentrant else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self.graph.on_acquire(self.name)
        return ok

    def release(self) -> None:
        # pop from the held stack BEFORE the real release, so another
        # thread's immediate acquire never sees us as still holding it
        self.graph.on_release(self.name)
        self._inner.release()

    def __enter__(self) -> "CheckedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class BlockingCallWatch:
    """Patch known blocking calls to record lock-held invocations.

    ``os.fsync`` and ``time.sleep`` are the two the serve stack actually
    makes; extend ``targets`` for others. Restores the originals on exit.
    """

    DEFAULT_TARGETS: Sequence[Tuple[object, str]] = (
        (os, "fsync"), (time, "sleep"))

    def __init__(self, graph: LockOrderGraph,
                 targets: Optional[Sequence[Tuple[object, str]]] = None):
        self.graph = graph
        self.targets = list(targets or self.DEFAULT_TARGETS)
        self._saved: List[Tuple[object, str, object]] = []

    def __enter__(self) -> "BlockingCallWatch":
        for mod, fname in self.targets:
            orig = getattr(mod, fname)
            self._saved.append((mod, fname, orig))

            def make(orig=orig, label=f"{mod.__name__}.{fname}"):
                def wrapper(*a, **k):
                    self.graph.note_blocking(label)
                    return orig(*a, **k)
                return wrapper

            setattr(mod, fname, make())
        return self

    def __exit__(self, *exc) -> bool:
        for mod, fname, orig in self._saved:
            setattr(mod, fname, orig)
        self._saved.clear()
        return False


def check_schedule(events: Iterable[Tuple[object, str, str]]
                   ) -> List[List[str]]:
    """Replay a simulated schedule of ``(thread_id, "acquire"|"release",
    lock_name)`` events through a fresh graph; returns its cycles. No real
    locks are taken, so a schedule whose interleaving WOULD deadlock is
    still fully analyzable."""
    g = LockOrderGraph()
    for thread_id, op, name in events:
        if op == "acquire":
            g.on_acquire(name, thread=thread_id)
        elif op == "release":
            g.on_release(name, thread=thread_id)
        else:
            raise ValueError(f"unknown schedule op {op!r}")
    return g.cycles()


def instrument(obj: object, graph: LockOrderGraph, name: str,
               attr: str = "lock", *, reentrant: bool = True) -> CheckedLock:
    """Replace ``obj.<attr>`` (an existing Lock/RLock) with a CheckedLock
    wired to ``graph``. Returns the wrapper."""
    if not hasattr(obj, attr):
        raise AttributeError(f"{obj!r} has no lock attribute {attr!r}")
    lock = CheckedLock(name, graph, reentrant=reentrant)
    setattr(obj, attr, lock)
    return lock
