"""The memlint rule set: one rule per serve-stack invariant.

Each rule names the PR that established its invariant (see
docs/INVARIANTS.md for the long-form rationale) and is deliberately
narrow — it matches the concrete syntactic shapes this repo uses, not
every conceivable violation, so a finding is near-certainly real and a
clean pass is cheap to keep. Every rule has a triggering fixture and a
clean-pass fixture in tests/test_analysis.py.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Set

from repro.analysis.core import ModuleCtx, rule


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------
def qualname(node: ast.AST) -> str:
    """Dotted name of a Name/Attribute chain ('' when not a plain chain)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def calls_in(node: ast.AST):
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            yield n


def _has_kw(call: ast.Call, name: str, value) -> bool:
    for kw in call.keywords:
        if kw.arg == name and isinstance(kw.value, ast.Constant) \
                and kw.value.value == value:
            return True
    return False


def _in_scope(ctx: ModuleCtx, *suffixes: str) -> bool:
    return any(s in ctx.rel if s.endswith("/") else ctx.rel.endswith(s)
               for s in suffixes)


# ---------------------------------------------------------------------------
# 1. deterministic top-k tie-break (PR 7: mesh/single-device exact parity)
# ---------------------------------------------------------------------------
@rule("topk-tiebreak",
      "top-k over similarity scores must use the deterministic "
      "(score desc, row id asc) tie-break — no lax.top_k, no unstable "
      "argsort — or mesh-sharded serve silently loses exact parity (PR 7)")
def topk_tiebreak(ctx: ModuleCtx) -> None:
    if not _in_scope(ctx, "repro/kernels/", "repro/core/retrieval.py",
                     "repro/core/residency.py"):
        return
    for call in calls_in(ctx.tree):
        q = qualname(call.func)
        if q.endswith("top_k") and ("lax" in q or q == "top_k"):
            ctx.report(call, "lax.top_k has implementation-defined tie "
                             "order; use a two-key lax.sort / merge_topk "
                             "(score desc, index asc)")
        elif q.endswith("argsort"):
            if not (_has_kw(call, "kind", "stable")
                    or _has_kw(call, "stable", True)):
                ctx.report(call, "unstable argsort on similarity scores "
                                 "breaks the (score desc, row id asc) "
                                 "tie-break contract; pass kind='stable' "
                                 "(numpy) or stable=True (jnp)")


# ---------------------------------------------------------------------------
# 2. commit-protocol renames are followed by a directory fsync (PR 3.1)
# ---------------------------------------------------------------------------
@rule("rename-fsync",
      "every os.rename/os.replace on a durability path must be followed by "
      "fsync_dir in the same function, or the committed directory entry can "
      "vanish on power loss and recovery drops acked writes (PR 3.1)")
def rename_fsync(ctx: ModuleCtx) -> None:
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        renames = []
        has_fsync_dir = False
        for call in calls_in(fn):
            q = qualname(call.func)
            if q in ("os.rename", "os.replace"):
                renames.append(call)
            elif q.endswith("fsync_dir"):
                has_fsync_dir = True
        # fsync_dir itself is the primitive; it contains no rename
        if renames and not has_fsync_dir and fn.name != "fsync_dir":
            for call in renames:
                ctx.report(call, f"os.{call.func.attr} in {fn.name}() has no "
                                 "fsync_dir in the same function — the "
                                 "renamed entry is not durable")


# ---------------------------------------------------------------------------
# 3. persistent-state mutations ride the journal (PR 3)
# ---------------------------------------------------------------------------
_MUTATORS = {"delete_session", "migrate_merge", "compact_tree"}
# journal.py IS the journaled path (ops + replay); maintenance.py defines the
# mutators (and may compose them internally).
_JOURNAL_MODULES = ("repro/core/journal.py", "repro/core/maintenance.py")


@rule("journaled-mutation",
      "persistent-state mutators (delete_session / migrate_merge / "
      "compact_tree) outside core/journal.py replay must route through a "
      "journaled DurableMemForest op, or a crash after the mutation "
      "recovers to a different state digest (PR 3)")
def journaled_mutation(ctx: ModuleCtx) -> None:
    if not ctx.rel.startswith("src/repro/") and "repro/" not in ctx.rel:
        return
    if _in_scope(ctx, *_JOURNAL_MODULES):
        return
    # bare names count only when imported from the maintenance module
    bare: Set[str] = set()
    for n in ast.walk(ctx.tree):
        if isinstance(n, ast.ImportFrom) and n.module \
                and n.module.endswith("maintenance"):
            bare.update(a.asname or a.name for a in n.names
                        if a.name in _MUTATORS)
    for call in calls_in(ctx.tree):
        q = qualname(call.func)
        name = q.rsplit(".", 1)[-1]
        if name not in _MUTATORS:
            continue
        if q.startswith("maintenance.") or q in bare:
            ctx.report(call, f"direct {name}() mutates persistent state "
                             "without a journal record; route through the "
                             "journaled DurableMemForest op")


# ---------------------------------------------------------------------------
# 4. replay / digest / snapshot determinism (PR 3)
# ---------------------------------------------------------------------------
_SET_ATTRS = {"applied_ops", "dirty_trees", "dirty"}
_DETERMINISM_SCOPE = ("repro/core/journal.py", "repro/core/persistence.py")


def _iter_nodes(tree: ast.AST):
    """(iterable expression, anchor node) pairs of every for-loop and
    comprehension generator."""
    for n in ast.walk(tree):
        if isinstance(n, (ast.For, ast.AsyncFor)):
            yield n.iter, n
        elif isinstance(n, (ast.ListComp, ast.SetComp, ast.DictComp,
                            ast.GeneratorExp)):
            for gen in n.generators:
                yield gen.iter, n


@rule("replay-determinism",
      "journal replay, digest computation, and snapshot serialization must "
      "be deterministic: no wall clocks, no random, no unordered-set "
      "iteration — or recovered state digests diverge run-to-run (PR 3)")
def replay_determinism(ctx: ModuleCtx) -> None:
    if not _in_scope(ctx, *_DETERMINISM_SCOPE):
        return
    for call in calls_in(ctx.tree):
        q = qualname(call.func)
        if q in ("time.time", "time.time_ns", "time.perf_counter",
                 "time.monotonic"):
            ctx.report(call, f"{q}() in a replay/serialization module makes "
                             "recovered state timing-dependent")
        elif q.startswith(("random.", "np.random.", "numpy.random.",
                           "jax.random.")):
            ctx.report(call, f"{q}() in a replay/serialization module makes "
                             "recovered state nondeterministic")
    for it, anchor in _iter_nodes(ctx.tree):
        if isinstance(it, ast.Set) \
                or (isinstance(it, ast.Call) and qualname(it.func) == "set"):
            ctx.report(anchor, "iterating a set directly: order is "
                               "arbitrary — wrap in sorted()")
        elif isinstance(it, ast.Attribute) and it.attr in _SET_ATTRS:
            ctx.report(anchor, f"iterating .{it.attr} (a set) directly: "
                               "order is arbitrary — wrap in sorted()")


# ---------------------------------------------------------------------------
# 5. spans only via context manager (PR 9)
# ---------------------------------------------------------------------------
@rule("span-context",
      "spans are opened only as `with obs.span(...)` — a manual __enter__ "
      "leaks the span onto the thread-local stack on any exception and "
      "corrupts every later span's parentage (PR 9)")
def span_context(ctx: ModuleCtx) -> None:
    if _in_scope(ctx, "repro/obs/"):
        return                      # the implementation layer itself
    with_items: Set[int] = set()
    for n in ast.walk(ctx.tree):
        if isinstance(n, (ast.With, ast.AsyncWith)):
            for item in n.items:
                with_items.add(id(item.context_expr))
    for call in calls_in(ctx.tree):
        func = call.func
        if isinstance(func, ast.Attribute) and func.attr == "span":
            if id(call) not in with_items:
                ctx.report(call, "span() result used outside a with "
                                 "statement — open spans only via the "
                                 "context manager")
        if isinstance(func, ast.Attribute) and func.attr == "__enter__":
            ctx.report(call, "manual __enter__ call — use a with statement")


# ---------------------------------------------------------------------------
# 6. every Pallas kernel has a referenced ref.py oracle (PRs 2/7)
# ---------------------------------------------------------------------------
# kernel module stem -> (ref.py oracle name, ops-layer entry point)
_KERNEL_ALIASES: Dict[str, str] = {"flash_attention": "attention"}


@rule("kernel-parity",
      "every Pallas kernel module in kernels/ needs a ref.py oracle that a "
      "parity test references — an unoracled kernel's numerics drift "
      "silently (PRs 2/7)")
def kernel_parity(ctx: ModuleCtx) -> None:
    parts = ctx.rel.split("/")
    if len(parts) < 2 or parts[-2] != "kernels":
        return
    stem = parts[-1][:-3]
    if stem in ("ref", "ops", "compat", "__init__"):
        return
    if not any(qualname(c.func).endswith("pallas_call")
               for c in calls_in(ctx.tree)):
        return
    base = _KERNEL_ALIASES.get(stem, stem)
    ref_name = f"{base}_ref"
    kernels_dir = os.path.dirname(ctx.path)
    if ref_name not in ctx.project.ref_functions(kernels_dir):
        ctx.report(1, f"Pallas kernel module has no {ref_name}() oracle in "
                      "kernels/ref.py")
        return
    tests = ctx.project.tests_text()
    if ref_name not in tests and f"ops.{base}(" not in tests:
        ctx.report(1, f"kernel oracle {ref_name}() is not referenced by any "
                      "test under tests/ — parity is unchecked")


# ---------------------------------------------------------------------------
# 7. no host sync inside ServeEngine.step phase bodies (PRs 1/2/9)
# ---------------------------------------------------------------------------
_PHASE_METHODS = {"step", "_admit", "_drain_ingest", "_drain_queries",
                  "_drain_maintenance", "_drain_residency"}
_SYNC_CALLS = {"np.asarray", "numpy.asarray", "jax.device_get"}


def _mentions_jax(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in ("jnp", "jax"):
            return True
    return False


@rule("host-sync",
      "no host-synchronizing calls (np.asarray / block_until_ready / "
      "device_get / float() on device arrays) inside ServeEngine.step "
      "phase bodies — a hidden sync serializes the decode cadence "
      "(PRs 1/2/9)")
def host_sync(ctx: ModuleCtx) -> None:
    if "serving/" not in ctx.rel:
        return
    engine_cls = next(
        (n for n in ast.walk(ctx.tree)
         if isinstance(n, ast.ClassDef) and n.name == "ServeEngine"), None)
    if engine_cls is None:
        return
    for fn in engine_cls.body:
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                or fn.name not in _PHASE_METHODS:
            continue
        for call in calls_in(fn):
            q = qualname(call.func)
            if q in _SYNC_CALLS:
                ctx.report(call, f"{q}() forces a device->host sync inside "
                                 f"{fn.name}()")
            elif isinstance(call.func, ast.Attribute) \
                    and call.func.attr == "block_until_ready":
                ctx.report(call, "block_until_ready() inside "
                                 f"{fn.name}() stalls the decode loop")
            elif q in ("float", "int") and call.args \
                    and _mentions_jax(call.args[0]):
                ctx.report(call, f"{q}() on a jax expression inside "
                                 f"{fn.name}() forces a device->host sync")
