"""memlint engine: AST rule registry, suppressions, baseline, file walker.

The serve stack's correctness rests on conventions that no type checker or
test can see from one file alone — the deterministic top-k tie-break that
mesh parity depends on, the fsync_dir after every commit-protocol rename,
the rule that persistent-state mutations ride the journal. ``memlint``
encodes each convention as a small AST rule (``repro/analysis/rules.py``)
and sweeps the tree on every CI run, so a refactor that silently drops one
fails the build instead of surfacing months later as stale answers.

Pieces:

  * **rule registry** — ``@rule("id", "one-line doc")`` registers a
    callback ``fn(ctx)`` that walks ``ctx.tree`` and calls
    ``ctx.report(node, message)``. Rules self-scope on ``ctx.rel`` (the
    file's path relative to the repo root), so fixtures in tests can
    reproduce any layout under a tmp dir.
  * **suppressions** — ``# memlint: ignore[rule-id]`` on the finding's
    line (or alone on the line above, for long statements) silences that
    rule there. ``ignore[*]`` silences every rule. Suppressions are meant
    to carry a justification comment — the sweep report counts them.
  * **baseline** — a committed JSON file of finding keys
    (``rule:path:line``) that are tolerated; ``--strict`` fails only on
    findings outside it. The goal state is an EMPTY baseline.
  * **repo root discovery** — walks up from the scanned path to the first
    directory holding ``tests/`` or ``.git`` (falls back to the scan
    path), so cross-file rules (kernel/ref parity) can find their
    counterparts in fixtures and in the real tree alike.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(r"#\s*memlint:\s*ignore\[([^\]]+)\]")


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # posix path relative to the repo root
    line: int
    message: str

    @property
    def key(self) -> str:
        """Baseline identity: stable across message rewording is NOT a goal
        (the baseline should be empty); stable across unrelated-file edits
        is, hence no content hash."""
        return f"{self.rule}:{self.path}:{self.line}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Rule:
    id: str
    doc: str
    fn: Callable[["ModuleCtx"], None]


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, doc: str):
    """Register a rule. The decorated function receives a :class:`ModuleCtx`
    per swept file and reports findings via ``ctx.report``."""
    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = Rule(rule_id, doc, fn)
        return fn
    return deco


# ---------------------------------------------------------------------------
# project-level context (cross-file rules)
# ---------------------------------------------------------------------------
class Project:
    """Lazy cross-file lookups shared by every ModuleCtx of one sweep."""

    def __init__(self, repo_root: str):
        self.repo_root = repo_root
        self._ref_functions: Dict[str, Set[str]] = {}
        self._tests_text: Optional[str] = None

    def ref_functions(self, kernels_dir: str) -> Set[str]:
        """Top-level function names defined in ``<kernels_dir>/ref.py``."""
        if kernels_dir not in self._ref_functions:
            names: Set[str] = set()
            path = os.path.join(kernels_dir, "ref.py")
            if os.path.exists(path):
                with open(path) as f:
                    src = f.read()
                try:
                    tree = ast.parse(src)
                except SyntaxError:
                    tree = ast.Module(body=[], type_ignores=[])
                names = {n.name for n in tree.body
                         if isinstance(n, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))}
            self._ref_functions[kernels_dir] = names
        return self._ref_functions[kernels_dir]

    def tests_text(self) -> str:
        """Concatenated source of every ``tests/**/*.py`` under the repo
        root (empty string when no tests dir exists)."""
        if self._tests_text is None:
            chunks: List[str] = []
            tdir = os.path.join(self.repo_root, "tests")
            if os.path.isdir(tdir):
                for base, _dirs, files in sorted(os.walk(tdir)):
                    for f in sorted(files):
                        if f.endswith(".py"):
                            with open(os.path.join(base, f)) as fh:
                                chunks.append(fh.read())
            self._tests_text = "\n".join(chunks)
        return self._tests_text


def find_repo_root(start: str) -> str:
    """Nearest ancestor of ``start`` containing ``tests/`` or ``.git``;
    ``start`` itself (its directory, for files) when none is found."""
    p = os.path.abspath(start)
    if os.path.isfile(p):
        p = os.path.dirname(p)
    cur = p
    while True:
        if os.path.isdir(os.path.join(cur, "tests")) \
                or os.path.isdir(os.path.join(cur, ".git")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return p
        cur = parent


# ---------------------------------------------------------------------------
# per-module context
# ---------------------------------------------------------------------------
class ModuleCtx:
    def __init__(self, path: str, rel: str, src: str, tree: ast.AST,
                 project: Project):
        self.path = path
        self.rel = rel                    # posix, relative to repo root
        self.src = src
        self.lines = src.splitlines()
        self.tree = tree
        self.project = project
        self._rule_id: Optional[str] = None
        self.findings: List[Finding] = []

    def report(self, where, message: str) -> None:
        """``where``: an AST node (uses .lineno) or an int line number."""
        line = where if isinstance(where, int) else getattr(where, "lineno", 1)
        self.findings.append(Finding(self._rule_id, self.rel, line, message))

    # -- suppression map ---------------------------------------------------
    def suppressions(self) -> Dict[int, Set[str]]:
        """line number -> rule ids suppressed there. A comment that is the
        whole line also suppresses the line below it (for statements too
        long to carry a trailing comment)."""
        out: Dict[int, Set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
            out.setdefault(i, set()).update(ids)
            if text.lstrip().startswith("#"):       # standalone comment line
                out.setdefault(i + 1, set()).update(ids)
        return out


# ---------------------------------------------------------------------------
# sweep
# ---------------------------------------------------------------------------
@dataclass
class SweepResult:
    findings: List[Finding] = field(default_factory=list)      # actionable
    suppressed: List[Finding] = field(default_factory=list)    # ignored inline
    baselined: List[Finding] = field(default_factory=list)     # tolerated
    stale_baseline: List[str] = field(default_factory=list)    # keys unmatched
    files_swept: int = 0

    @property
    def clean(self) -> bool:
        return not self.findings


def iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for base, dirs, files in sorted(os.walk(p)):
            dirs[:] = sorted(d for d in dirs
                             if d not in ("__pycache__", ".git"))
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(base, f)


def load_baseline(path: Optional[str]) -> Set[str]:
    if not path or not os.path.exists(path):
        return set()
    with open(path) as f:
        doc = json.load(f)
    return set(doc.get("findings", []))


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    doc = {"version": 1, "findings": sorted(f.key for f in findings)}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def run_paths(paths: Sequence[str], *, rules: Optional[Sequence[str]] = None,
              repo_root: Optional[str] = None,
              baseline: Optional[Set[str]] = None) -> SweepResult:
    """Sweep ``paths`` with the registered rules (all by default).

    Returns a :class:`SweepResult` with inline-suppressed and baselined
    findings separated out; ``result.findings`` is what --strict gates on.
    """
    # rules register on import; tolerate being called before rules.py loaded
    from repro.analysis import rules as _rules  # noqa: F401

    active = [RULES[r] for r in rules] if rules else list(RULES.values())
    root = repo_root or find_repo_root(paths[0] if paths else ".")
    project = Project(root)
    base = baseline or set()
    res = SweepResult()
    matched_base: Set[str] = set()

    for path in iter_py_files(paths):
        res.files_swept += 1
        with open(path) as f:
            src = f.read()
        rel = os.path.relpath(os.path.abspath(path), root).replace(os.sep, "/")
        try:
            tree = ast.parse(src)
        except SyntaxError as e:
            res.findings.append(Finding(
                "parse-error", rel, e.lineno or 1, f"syntax error: {e.msg}"))
            continue
        ctx = ModuleCtx(path, rel, src, tree, project)
        for r in active:
            ctx._rule_id = r.id
            r.fn(ctx)
        sup = ctx.suppressions()
        for f in ctx.findings:
            ids = sup.get(f.line, set())
            if f.rule in ids or "*" in ids:
                res.suppressed.append(f)
            elif f.key in base:
                res.baselined.append(f)
                matched_base.add(f.key)
            else:
                res.findings.append(f)
    res.stale_baseline = sorted(base - matched_base)
    res.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return res
