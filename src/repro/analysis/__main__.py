"""memlint CLI: ``python -m repro.analysis src/ [--strict]``.

Exit status: 0 when every finding is inline-suppressed or baselined;
1 under ``--strict`` when actionable findings (or a syntax error) remain.
Without ``--strict`` the sweep is report-only (exit 0), which is the
local-iteration mode; CI runs ``--strict`` with the committed (empty)
baseline, so any new unsuppressed finding fails the build.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis import core
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)

DEFAULT_BASELINE = "memlint_baseline.json"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="memlint: serve-stack invariant checker")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to sweep (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on unsuppressed, un-baselined findings")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON path (default: <repo root>/"
                         f"{DEFAULT_BASELINE} when it exists)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit 0")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON instead of text")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in sorted(core.RULES.values(), key=lambda r: r.id):
            print(f"{r.id:20s} {r.doc}")
        return 0

    paths = args.paths or ["src"]
    repo_root = core.find_repo_root(paths[0])
    baseline_path = args.baseline
    if baseline_path is None:
        cand = os.path.join(repo_root, DEFAULT_BASELINE)
        baseline_path = cand if os.path.exists(cand) else None

    rule_ids = [s.strip() for s in args.rules.split(",")] if args.rules else None
    res = core.run_paths(paths, rules=rule_ids, repo_root=repo_root,
                         baseline=core.load_baseline(baseline_path))

    if args.write_baseline:
        out = baseline_path or os.path.join(repo_root, DEFAULT_BASELINE)
        core.write_baseline(out, res.findings)
        print(f"memlint: wrote {len(res.findings)} finding(s) to {out}")
        return 0

    if args.json:
        print(json.dumps({
            "findings": [f.__dict__ for f in res.findings],
            "suppressed": len(res.suppressed),
            "baselined": len(res.baselined),
            "stale_baseline": res.stale_baseline,
            "files_swept": res.files_swept,
        }, indent=2))
    else:
        for f in res.findings:
            print(f.render())
        for key in res.stale_baseline:
            print(f"stale baseline entry (no longer fires): {key}")
        print(f"memlint: {res.files_swept} files, "
              f"{len(res.findings)} finding(s), "
              f"{len(res.suppressed)} suppressed, "
              f"{len(res.baselined)} baselined")

    if args.strict and res.findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
