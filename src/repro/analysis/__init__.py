"""memlint: repo-specific static analysis + dynamic lock-order checking.

The serve stack's invariants (deterministic top-k tie-break, fsync-after-
rename, journaled persistent-state mutation, replay determinism, span
discipline, kernel/ref parity, no host sync in the decode loop) live here
as enforced rules instead of review lore:

  * ``python -m repro.analysis src/ --strict`` — the AST sweep
    (repro/analysis/rules.py; engine in repro/analysis/core.py).
  * ``repro.analysis.lockcheck`` — an instrumented Lock wrapper that
    records the cross-thread lock-acquisition graph, flags cycles (the
    deadlock precondition) and lock-held blocking calls; driven by
    tests/test_lockcheck.py under concurrent engine + maintenance +
    residency traffic.

See README "Static analysis" and docs/INVARIANTS.md.
"""
from repro.analysis.core import (Finding, RULES, Rule, SweepResult,
                                 find_repo_root, load_baseline, rule,
                                 run_paths, write_baseline)
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)

__all__ = ["Finding", "RULES", "Rule", "SweepResult", "find_repo_root",
           "load_baseline", "rule", "run_paths", "write_baseline"]
