"""Chunked Mamba2 SSD scan — Pallas TPU kernel.

Recurrence (per head; S is the (P, N) state; scalar decay per head):

    S_t = exp(dt_t A_h) S_{t-1} + dt_t x_t ⊗ B_t
    y_t = S_t C_t

Because the decay is a *scalar* per head (Mamba2's SSD restriction), the
chunked factorization is unconditionally stable: with cumulative log-decay
cum[t] = Σ_{i<=t} dt_i A_h (A_h < 0 so cum is decreasing),

    y_intra[t] = Σ_{s<=t} exp(cum[t]-cum[s]) dt_s (C_t·B_s) x_s
    y_inter[t] = exp(cum[t]) (S_in C_t)
    S_out      = exp(cum[C-1]) S_in + Σ_s exp(cum[C-1]-cum[s]) dt_s x_s ⊗ B_s

and every exponent is <= 0. The intra-chunk term is two MXU matmuls:
G = (C Bᵀ) ⊙ decay-mask (C x C), then G @ x.

Grid: (batch, heads, num_chunks), chunks innermost/sequential, (P, N) fp32
state in VMEM scratch. B/C are shared across heads (single SSD group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_CHUNK = 64


def _ssd_kernel(
    x_ref,    # (1, 1, C, P)
    dt_ref,   # (1, 1, C, 1)
    a_ref,    # (1, 1) — A_h (negative scalar)
    b_ref,    # (1, C, N)
    c_ref,    # (1, C, N)
    s0_ref,   # (1, 1, P, N)
    y_ref,    # (1, 1, C, P)
    sout_ref, # (1, 1, P, N)
    state_ref,  # scratch (P, N) f32
    *,
    chunk: int,
    num_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)     # (C, P)
    dt = dt_ref[0, 0].astype(jnp.float32)   # (C, 1)
    A = a_ref[0, 0].astype(jnp.float32)     # scalar
    Bm = b_ref[0].astype(jnp.float32)       # (C, N)
    Cm = c_ref[0].astype(jnp.float32)       # (C, N)

    cum = jnp.cumsum(dt * A, axis=0)        # (C, 1), decreasing
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # decay[t,s] = exp(cum[t]-cum[s]) for s <= t else 0
    dmat = jnp.where(
        t_idx >= s_idx,
        jnp.exp(jnp.minimum(cum - cum.T, 0.0)),
        0.0,
    )                                        # (C, C)
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (C, C): C_t · B_s
    G = cb * dmat * dt.T                     # (C, C) — includes dt_s
    y_intra = jax.lax.dot_general(
        G, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (C, P)
    # inter: y_inter[t] = exp(cum[t]) * C_t @ S_inᵀ  -> (C, P)
    y_inter = jnp.exp(cum) * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    total = cum[chunk - 1]                   # (1,)
    xw = x * (dt * jnp.exp(jnp.minimum(total[None, :] - cum, 0.0)))  # (C, P)
    s_new = jnp.exp(total)[:, None] * state_ref[...] + jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (P, N)
    state_ref[...] = s_new

    @pl.when(ic == num_chunks - 1)
    def _finish():
        sout_ref[0, 0] = s_new.astype(sout_ref.dtype)


def mamba2_ssd(
    x: jax.Array,      # (B, T, H, P)
    dt: jax.Array,     # (B, T, H) — softplus'd, > 0
    A: jax.Array,      # (H,) — negative
    Bm: jax.Array,     # (B, T, N)
    C: jax.Array,      # (B, T, N)
    state: jax.Array,  # (B, H, P, N)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    xt = x.transpose(0, 2, 1, 3)                    # (B, H, T, P)
    dtt = dt.transpose(0, 2, 1)[..., None]          # (B, H, T, 1)
    a2 = A.reshape(H, 1).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, s_final = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), state.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xt, dtt, a2, Bm, C, state)
    return y.transpose(0, 2, 1, 3), s_final
