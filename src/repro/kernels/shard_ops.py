"""Mesh-sharded dispatch for the serve-path kernels (multi-device serve).

The batch dimensions PRs 1-2 created — the fact-index top-k scan, the
(query, tree) browse-lane frontier, and the cross-tree ``tree_refresh``
flush batch — are embarrassingly parallel. This module places them on a
1-D ``data``-axis mesh (launch/mesh.py) with ``shard_map``:

* ``sharded_topk_sim`` — the fact index rows are sharded; each device runs
  the SAME fused top-k kernel (reference or Pallas) over its local rows,
  then an all-gather of (score, global row) candidates + a two-key sort
  (``topk_sim.merge_topk``) produces the exact global top-k on every
  device. The merge moves S*k candidates, never the (Q, N) score matrix.
* ``sharded_scatter_rows`` / ``upload_sharded`` / ``grow_sharded`` — the
  device-resident index cache's lifecycle under sharding, with per-shard
  row ownership (each shard applies only the updates it owns).
* ``sharded_tree_refresh`` / ``sharded_browse_scores`` — pure data
  parallelism over the parent/frontier dim; per-row math is row-local, so
  results are bitwise identical to the single-device launch.

Row ownership is ROUND-ROBIN: global row g lives on shard ``g % S`` at
local slot ``g // S``. The physical (C, D) array is the shard-major
permutation of the logical matrix (shard 0's strided rows first), sharded
contiguously over the data axis, so each shard's contiguous block IS its
strided row subset. Why round-robin instead of contiguous blocks: capacity
growth appends slots to EVERY shard's local block (a shard-local pad), so
geometric device-cache growth never moves an existing row across devices —
no resharding traffic on the steady-ingest path.

Exactness: per-row scores/normalization/refresh math touch only that row's
values, so sharded results are bitwise identical to single-device; with the
deterministic (score desc, row id asc) tie-break shared by every top-k
path, mesh=None and any mesh size are exactly result-identical.

All builders are cached per (mesh, static shape bucket) so the jit-compile
set stays bounded; meshes are hashable and close over their devices.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.kernels import ref as _ref
from repro.kernels.browse_scores import browse_scores as _browse
from repro.kernels.topk_sim import NEG_INF, merge_topk
from repro.kernels.topk_sim import topk_sim as _topk
from repro.kernels.tree_refresh import tree_refresh as _tree_refresh


def mesh_shards(mesh: Optional[Mesh], axis: str = "data") -> int:
    """Data-axis width of ``mesh`` (1 when mesh is None / axis absent)."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(axis, 1))


def pad_rows(n: int, mult: int) -> int:
    """Round ``n`` up to a multiple of ``mult`` (shard-divisible padding)."""
    return -(-n // mult) * mult


def _normalize(x):
    # identical formula to ops.normalize_rows — row-local, so bitwise equal
    # whether applied to the whole matrix or a shard's block
    xf = x.astype(jnp.float32)
    return xf / (jnp.linalg.norm(xf, axis=-1, keepdims=True) + 1e-6)


# ---------------------------------------------------------------------------
# sharded index-cache lifecycle (upload / grow / scatter)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _normalize_sharded(mesh: Mesh, axis: str):
    return jax.jit(shard_map(
        _normalize, mesh=mesh,
        in_specs=P(axis, None), out_specs=P(axis, None)))


def upload_sharded(host: np.ndarray, cap: int, mesh: Mesh, axis: str = "data"):
    """Full upload of a host matrix into the round-robin sharded layout.
    ``cap`` must be a multiple of the mesh's data-axis size; rows beyond the
    host matrix pad with zeros (masked by num_valid downstream)."""
    S = mesh_shards(mesh, axis)
    dim = host.shape[1]
    hp = np.zeros((cap, dim), np.float32)
    hp[: host.shape[0]] = host
    # shard-major permutation: physical row s*(cap//S)+l <- logical row l*S+s
    perm = hp.reshape(cap // S, S, dim).transpose(1, 0, 2).reshape(cap, dim)
    arr = jax.device_put(perm, NamedSharding(mesh, P(axis, None)))
    return _normalize_sharded(mesh, axis)(arr)


def upload_replicated(host: np.ndarray, mesh: Mesh):
    """Full upload of a host matrix replicated across the mesh (the root
    index: small, read by every shard's recall)."""
    arr = jax.device_put(np.ascontiguousarray(host, np.float32),
                         NamedSharding(mesh, P(None, None)))
    return jax.jit(_normalize)(arr)


@functools.lru_cache(maxsize=None)
def _grow_sharded(mesh: Mesh, axis: str, add_per_shard: int):
    def body(a):
        return jnp.pad(a, ((0, add_per_shard), (0, 0)))
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=P(axis, None), out_specs=P(axis, None)))


def grow_sharded(arr, new_cap: int, mesh: Mesh, axis: str = "data"):
    """Geometric device-cache growth under sharding: every shard pads its
    local block — existing rows keep their owner, nothing crosses devices."""
    S = mesh_shards(mesh, axis)
    add = (new_cap - arr.shape[0]) // S
    return _grow_sharded(mesh, axis, add)(arr)


@functools.lru_cache(maxsize=None)
def _scatter_sharded(mesh: Mesh, axis: str):
    S = mesh_shards(mesh, axis)

    def body(a, idx, rows):
        s = jax.lax.axis_index(axis)
        # per-shard row ownership: this shard applies only the updates for
        # rows it owns; everything else (and -1 padding) drops out of bounds
        mine = (idx >= 0) & (idx % S == s.astype(idx.dtype))
        li = jnp.where(mine, idx // S, a.shape[0])
        return a.at[li].set(_normalize(rows), mode="drop")

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None), P(None), P(None, None)),
        out_specs=P(axis, None)))


def sharded_scatter_rows(arr, idx, rows, *, mesh: Mesh, axis: str = "data"):
    """Incremental sharded-index update: normalized ``rows`` land at global
    row ids ``idx`` (int32; -1 entries are padding and dropped)."""
    return _scatter_sharded(mesh, axis)(arr, jnp.asarray(idx, jnp.int32),
                                        jnp.asarray(rows))


# ---------------------------------------------------------------------------
# sharded fused top-k scan
# ---------------------------------------------------------------------------
def _local_topk(q, kk, k, num_valid, impl):
    if impl == "reference":
        return _ref.topk_sim_ref(q, kk, k, normalize=False,
                                 num_valid=num_valid)
    return _topk(q, kk, k, normalize=False, num_valid=num_valid,
                 interpret=(impl == "pallas_interpret"))


@functools.lru_cache(maxsize=None)
def _topk_sharded(mesh: Mesh, axis: str, k: int, k_local: int, impl: str):
    S = mesh_shards(mesh, axis)

    def body(nv, q, kk):
        s = jax.lax.axis_index(axis).astype(jnp.int32)
        # valid rows this shard owns: #{g < nv : g % S == s}
        local_nv = jnp.maximum((nv - s + S - 1) // S, 0)
        vals, idx = _local_topk(q, kk, k_local, local_nv, impl)
        gidx = jnp.where(idx >= 0, idx * S + s, -1)
        vals = jnp.where(idx >= 0, vals, NEG_INF)
        av = jax.lax.all_gather(vals, axis)            # (S, Q, k_local)
        ai = jax.lax.all_gather(gidx, axis)
        pool_v = jnp.moveaxis(av, 0, 1).reshape(q.shape[0], S * k_local)
        pool_i = jnp.moveaxis(ai, 0, 1).reshape(q.shape[0], S * k_local)
        return merge_topk(pool_v, pool_i, k)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), P(None, None), P(axis, None)),
                   out_specs=(P(None, None), P(None, None)),
                   check_rep=False)
    return jax.jit(fn)


def sharded_topk_sim(queries, keys, k: int, *, mesh: Mesh, axis: str = "data",
                     num_valid=None, impl: str = "reference"):
    """Fused top-k over a round-robin sharded key matrix: shard-local top-k
    + cross-device candidate merge. ``queries`` must be pre-normalized (the
    sharded cache stores normalized rows); returns (vals, idx) with GLOBAL
    row indices, exactly equal to the single-device ``topk_sim`` result."""
    S = mesh_shards(mesh, axis)
    shard_rows = keys.shape[0] // S
    k_local = min(k, shard_rows)
    nv = jnp.asarray(keys.shape[0] if num_valid is None else num_valid,
                     jnp.int32)
    return _topk_sharded(mesh, axis, k, k_local, impl)(nv, queries, keys)


# ---------------------------------------------------------------------------
# sharded flush / browse batches (pure data parallelism over the batch dim)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _tree_refresh_sharded(mesh: Mesh, axis: str, impl: str):
    def body(emb, mask):
        if impl == "reference":
            return _ref.tree_refresh_ref(emb, mask)
        return _tree_refresh(emb, mask,
                             interpret=(impl == "pallas_interpret"))

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None)),
        out_specs=P(axis, None)))


def sharded_tree_refresh(child_emb, child_mask, *, mesh: Mesh,
                         axis: str = "data", impl: str = "reference"):
    """One flush level's (P, K, D) cross-tree refresh batch, parents sharded
    over the mesh. P must be a multiple of the data-axis size (the Forest
    pads its power-of-two bucket up to a shard multiple)."""
    return _tree_refresh_sharded(mesh, axis, impl)(
        jnp.asarray(child_emb), jnp.asarray(child_mask))


@functools.lru_cache(maxsize=None)
def _browse_sharded(mesh: Mesh, axis: str, impl: str):
    def body(emb, q, mask):
        if impl == "reference":
            return _ref.browse_scores_ref(emb, q, mask)
        return _browse(emb, q, mask, interpret=(impl == "pallas_interpret"))

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None), P(axis, None)),
        out_specs=P(axis, None)))


def sharded_browse_scores(child_emb, q_emb, child_mask, *, mesh: Mesh,
                          axis: str = "data", impl: str = "reference"):
    """One browse depth level's packed (F, K, D) frontier, lanes sharded
    over the mesh. F must be a multiple of the data-axis size (the
    Retriever pads its power-of-two bucket up to a shard multiple)."""
    return _browse_sharded(mesh, axis, impl)(
        jnp.asarray(child_emb), jnp.asarray(q_emb), jnp.asarray(child_mask))
