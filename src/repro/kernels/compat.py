"""Pallas API compat across JAX versions.

``pltpu.CompilerParams`` is the current name; the pinned JAX still calls it
``TPUCompilerParams``. Kernels import :data:`CompilerParams` from here so the
same source builds against either.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
