"""Single-token GQA decode attention over a KV cache — Pallas TPU kernel.

Decode is memory-bound: the kernel streams the KV cache through VMEM in
(block_kv, D) tiles while the q tile for one whole GQA group (all query heads
sharing a KV head) stays resident. Grid: (batch, kv_heads, num_kv_blocks),
KV innermost/sequential with fp32 online-softmax scratch.

Variable cache lengths are handled with a per-sequence length input; slots at
or beyond the length are masked. The cache layout is (B, S, Hkv, D) — the
same layout `models.transformer` maintains — transposed to (B, Hkv, S, D)
outside the kernel so tiles are contiguous along the streamed axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_BLOCK_KV = 1024
NEG_INF = -1e30


def _decode_kernel(
    len_ref,                  # (1, 1) int32
    q_ref,                    # (1, 1, G, D)
    k_ref, v_ref,             # (1, 1, bk, D)
    o_ref,                    # (1, 1, G, D)
    acc_ref, m_ref, l_ref,    # scratch: (G, D) f32, (G, 1) f32, (G, 1) f32
    *,
    block_kv: int,
    num_kv_blocks: int,
    sm_scale: float,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0, 0]
    # Skip blocks entirely beyond the valid cache length.
    @pl.when(ik * block_kv < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)        # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                # (G, bk)
        pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,        # (B, Hq, D) — one new token per sequence
    k_cache: jax.Array,  # (B, Smax, Hkv, D)
    v_cache: jax.Array,  # (B, Smax, Hkv, D)
    lengths: jax.Array,  # (B,) int32
    *,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    B, Smax, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    group = Hq // Hkv
    block_kv = min(block_kv, Smax)
    assert Smax % block_kv == 0, (Smax, block_kv)
    nkv = Smax // block_kv
    sm_scale = 1.0 / (D ** 0.5)

    qg = q.reshape(B, Hkv, group, D)
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, Hkv, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    len2d = lengths.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(
        _decode_kernel,
        block_kv=block_kv,
        num_kv_blocks=nkv,
        sm_scale=sm_scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nkv),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0)),
            pl.BlockSpec((1, 1, group, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, D), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(len2d, qg, kt, vt)
    return out.reshape(B, Hq, D)
