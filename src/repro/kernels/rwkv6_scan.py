"""Chunked RWKV6 (Finch) WKV recurrence — Pallas TPU kernel.

Recurrence (per head; S is the (K, V) state matrix):

    o_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t = diag(exp(-exp(w_t))) S_{t-1} + k_tᵀ v_t

The kernel processes the sequence in chunks of C tokens. Within a chunk the
pairwise token interactions are computed directly from per-key cumulative
log-decays (no exp(+cum) factorization — the (C, C, K) log-difference form is
exact and stable because every exponent is <= 0):

    cum[t]   = Σ_{i<=t} -exp(w_i)                      (C, K), decreasing
    A[t,s]   = Σ_k r[t,k] k[s,k] exp(cum[t-1,k]-cum[s,k])   for s < t
    A[t,t]   = Σ_k r[t,k] u[k] k[t,k]
    o        = A @ v + (r ⊙ exp(cum_excl)) @ S_in
    S_out    = exp(cum[C-1]) ⊙ S_in + Σ_s (k_s ⊙ exp(cum[C-1]-cum[s]))ᵀ v_s

Grid: (batch, heads, num_chunks), chunks innermost/sequential; the (K, V)
state lives in fp32 VMEM scratch across chunk iterations. The O(C²K)
intra-chunk tensor is the TPU-native replacement for the GPU kernel's
warp-level recurrence: at C = 64, K = 64 it is a 1 MB fp32 VMEM tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_CHUNK = 64


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, w_ref,  # (1,1,C,K) / (1,1,C,V) / (1,1,C,K)
    u_ref,                        # (1, K)
    s0_ref,                       # (1, 1, K, V) initial state
    o_ref,                        # (1, 1, C, V)
    sout_ref,                     # (1, 1, K, V) final state
    state_ref,                    # scratch (K, V) f32
    *,
    chunk: int,
    num_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)   # (C, K)
    k = k_ref[0, 0].astype(jnp.float32)   # (C, K)
    v = v_ref[0, 0].astype(jnp.float32)   # (C, V)
    w = w_ref[0, 0].astype(jnp.float32)   # (C, K)
    u = u_ref[0].astype(jnp.float32)      # (K,)

    logdec = -jnp.exp(w)                              # (C, K) <= 0
    cum = jnp.cumsum(logdec, axis=0)                  # inclusive, (C, K)
    cum_excl = cum - logdec                           # exclusive (cum[t-1])

    # inter-chunk: contribution of carried state
    r_scaled = r * jnp.exp(cum_excl)                  # (C, K)
    o_inter = jax.lax.dot_general(
        r_scaled, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # (C, V)

    # intra-chunk: exact pairwise log-difference form (all exponents <= 0)
    # diff[t,s,k] = cum_excl[t,k] - cum[s,k]  (valid for s < t)
    diff = cum_excl[:, None, :] - cum[None, :, :]     # (C, C, K)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = t_idx > s_idx
    gate = jnp.where(strict[..., None], jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    A = jnp.einsum("tk,sk,tsk->ts", r, k, gate)       # (C, C) strictly lower
    A_diag = jnp.sum(r * u[None, :] * k, axis=1)      # (C,)
    A = A + jnp.where(t_idx == s_idx, A_diag[:, None], 0.0)
    o_intra = jax.lax.dot_general(
        A, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, 0] = (o_inter + o_intra).astype(o_ref.dtype)

    # state update
    total = cum[chunk - 1]                            # (K,)
    k_scaled = k * jnp.exp(total[None, :] - cum)      # (C, K), exponents <= 0
    s_new = jnp.exp(total)[:, None] * state_ref[...] + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                 # (K, V)
    state_ref[...] = s_new

    @pl.when(ic == num_chunks - 1)
    def _finish():
        sout_ref[0, 0] = s_new.astype(sout_ref.dtype)


def rwkv6_scan(
    r: jax.Array,      # (B, T, H, K)
    k: jax.Array,      # (B, T, H, K)
    v: jax.Array,      # (B, T, H, V)
    w: jax.Array,      # (B, T, H, K) raw; decay = exp(-exp(w))
    u: jax.Array,      # (H, K)
    state: jax.Array,  # (B, H, K, V)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    B, T, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    # layout: (B, H, T, •)
    rt = r.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    wt = w.transpose(0, 2, 1, 3)
    s0 = state[:, :, None].reshape(B, H, K, V)

    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, num_chunks=nc)
    o, s_final = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), state.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(rt, kt, vt, wt, u, s0)
    return o.transpose(0, 2, 1, 3), s_final
