"""Tiled causal GQA flash attention (prefill) — Pallas TPU kernel.

Grid layout: (batch, q_heads, num_q_blocks, num_kv_blocks) with the KV-block
dimension innermost and sequential ("arbitrary"), so the running softmax
statistics (m, l) and the fp32 output accumulator live in VMEM scratch and
carry across KV iterations. Causal blocks above the diagonal are skipped.

VMEM working set per step: q tile (block_q, D) + k/v tiles (block_kv, D) each
in input dtype, plus fp32 scratch (block_q, D) + 2*(block_q, 1). With the
default block_q = block_kv = 512 and D = 128 that is ~0.7 MB — comfortably
inside VMEM — and MXU contractions are (512 x 128 x 512), all multiples of
the 128-lane systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,      # (1,1,bq,D), (1,1,bk,D), (1,1,bk,D)
    o_ref,                    # (1,1,bq,D)
    acc_ref, m_ref, l_ref,    # scratch: (bq,D) f32, (bq,1) f32, (bq,1) f32
    *,
    causal: bool,
    block_q: int,
    block_kv: int,
    num_kv_blocks: int,
    sm_scale: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Skip KV blocks entirely above the causal diagonal.
    if causal:
        run = ik * block_kv <= iq * block_q + block_q - 1
    else:
        run = ik >= 0  # always true, keeps a traced bool

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                   # (bq, bk)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,  # (B, S, Hkv, D)
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    nq = S // block_q
    nkv = S // block_kv
    sm_scale = 1.0 / (D ** 0.5)

    qt = q.transpose(0, 2, 1, 3)  # (B, H, S, D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nkv,
        sm_scale=sm_scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)  # (B, S, Hq, D)
