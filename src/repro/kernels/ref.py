"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth used by the per-kernel allclose tests and by the
models when ``attention_impl == "reference"`` (the CPU dry-run path). They are
written for clarity and exactness, not speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# attention (prefill, causal, GQA)
# --------------------------------------------------------------------------
def attention_ref(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,  # (B, S, Hkv, D)
    *,
    causal: bool = True,
) -> jax.Array:
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def cross_attention_ref(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, D)
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# decode attention (single new token vs KV cache)
# --------------------------------------------------------------------------
def decode_attention_ref(
    q: jax.Array,        # (B, Hq, D) — one new token per sequence
    k_cache: jax.Array,  # (B, Smax, Hkv, D)
    v_cache: jax.Array,  # (B, Smax, Hkv, D)
    lengths: jax.Array,  # (B,) int32 — valid cache entries per sequence
) -> jax.Array:
    B, Smax, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    group = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, kf) * scale
    pos = jnp.arange(Smax)[None, None, None, :]
    valid = pos < lengths[:, None, None, None]
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, vf)
    return out.reshape(B, Hq, D).astype(q.dtype)


# --------------------------------------------------------------------------
# fused similarity + top-k (forest recall / fact recall hot path)
# --------------------------------------------------------------------------
def topk_sim_ref(
    queries: jax.Array,  # (Q, D)
    keys: jax.Array,     # (N, D)
    k: int,
    *,
    normalize: bool = True,
    num_valid=None,      # optional traced scalar: rows >= num_valid masked out
):
    qf = queries.astype(jnp.float32)
    kf = keys.astype(jnp.float32)
    if normalize:
        qf = qf / (jnp.linalg.norm(qf, axis=-1, keepdims=True) + 1e-6)
        kf = kf / (jnp.linalg.norm(kf, axis=-1, keepdims=True) + 1e-6)
    scores = qf @ kf.T  # (Q, N)
    if num_valid is not None:
        cols = jnp.arange(scores.shape[1])[None, :]
        scores = jnp.where(cols < num_valid, scores, -1e30)
    # deterministic selection ordered by (score desc, key index asc) — the
    # same tie-break the Pallas kernel's first-occurrence argmax applies and
    # the cross-shard candidate merge (topk_sim.merge_topk) relies on for
    # exact single-device/multi-device parity. lax.top_k's tie order is
    # backend-defined, so the lexicographic two-key sort is explicit here.
    cols = jnp.broadcast_to(
        jnp.arange(scores.shape[1], dtype=jnp.int32)[None, :], scores.shape)
    sneg, sidx = jax.lax.sort((-scores, cols), dimension=-1, num_keys=2)
    vals = -sneg[:, :k]
    idx = jnp.where(vals > -1e29, sidx[:, :k], -1)
    return vals, idx.astype(jnp.int32)


# --------------------------------------------------------------------------
# level-synchronous browse scoring: per-frontier-entry masked matvec
# --------------------------------------------------------------------------
def browse_scores_ref(
    child_emb: jax.Array,   # (F, K, D) — packed frontier children
    q_emb: jax.Array,       # (F, D) — per-entry query vector
    child_mask: jax.Array,  # (F, K) — 1.0 for real child slots
):
    s = jnp.einsum(
        "fkd,fd->fk", child_emb.astype(jnp.float32), q_emb.astype(jnp.float32)
    )
    return s * child_mask.astype(jnp.float32)


# --------------------------------------------------------------------------
# tree refresh: masked segment-mean of child embeddings -> parent embedding
# --------------------------------------------------------------------------
def tree_refresh_ref(
    child_emb: jax.Array,   # (P, K, D) — padded children per dirty parent
    child_mask: jax.Array,  # (P, K) bool/float — which slots are real children
) -> jax.Array:
    m = child_mask.astype(jnp.float32)[..., None]          # (P, K, 1)
    s = jnp.sum(child_emb.astype(jnp.float32) * m, axis=1)  # (P, D)
    cnt = jnp.maximum(jnp.sum(m, axis=1), 1.0)              # (P, 1)
    mean = s / cnt
    norm = jnp.linalg.norm(mean, axis=-1, keepdims=True) + 1e-6
    return (mean / norm).astype(child_emb.dtype)


# --------------------------------------------------------------------------
# RWKV6 (Finch) WKV recurrence with data-dependent decay
# --------------------------------------------------------------------------
def rwkv6_scan_ref(
    r: jax.Array,      # (B, T, H, K)
    k: jax.Array,      # (B, T, H, K)
    v: jax.Array,      # (B, T, H, V)
    w: jax.Array,      # (B, T, H, K) raw; decay = exp(-exp(w))
    u: jax.Array,      # (H, K) bonus
    state: jax.Array,  # (B, H, K, V) carried state
):
    """Exact sequential recurrence.

        o_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
        S_t = diag(exp(-exp(w_t))) S_{t-1} + k_tᵀ v_t
    """
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    s0 = state.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,K,V)
        o = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s_new = jnp.exp(-jnp.exp(wt))[..., None] * s + kv
        return s_new, o

    xs = (
        jnp.moveaxis(rf, 1, 0),
        jnp.moveaxis(kf, 1, 0),
        jnp.moveaxis(vf, 1, 0),
        jnp.moveaxis(wf, 1, 0),
    )
    s_final, outs = jax.lax.scan(step, s0, xs)
    out = jnp.moveaxis(outs, 0, 1)  # (B, T, H, V)
    return out.astype(r.dtype), s_final.astype(state.dtype)


# --------------------------------------------------------------------------
# model-grade chunked implementations (memory-sane XLA fallbacks; same math
# as the Pallas kernels — these are what the models lower on the CPU dry-run)
# --------------------------------------------------------------------------
def blockwise_causal_attention(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,  # (B, S, Hkv, D)
    *,
    block_q: int = 0,      # 0 -> pick so there are <= 16 q blocks
    block_kv: int = 512,
) -> jax.Array:
    """Exact-FLOPs causal attention: python loop over q blocks, each block
    attends to its *static* KV prefix with an online-softmax scan over KV
    chunks. No (S, S) logits materialization, no above-diagonal compute
    (except intra-diagonal-block masking) — this is flash attention in XLA.
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    if block_q == 0:
        block_q = max(-(-S // 16), 128)
        block_q = min(block_q, S)
    while S % block_q:
        block_q //= 2
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    nq = S // block_q

    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B, H, S, D)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3)

    outs = []
    for iq in range(nq):
        q_blk = qf[:, :, iq * block_q:(iq + 1) * block_q]       # (B,H,bq,D)
        kv_len = (iq + 1) * block_q                              # static prefix
        bkv = min(block_kv, kv_len)
        while kv_len % bkv:
            bkv //= 2
        nkv = kv_len // bkv
        k_pre = kf[:, :, :kv_len].reshape(B, Hq, nkv, bkv, D)
        v_pre = vf[:, :, :kv_len].reshape(B, Hq, nkv, bkv, D)

        def kv_step(carry, kv, _iq=iq, _bkv=bkv):
            m, l, acc, ik = carry
            kb, vb = kv                                          # (B,H,bkv,D)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, kb) * scale
            rows = _iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
            cols = ik * _bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
            s = jnp.where(rows >= cols, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
            return (m_new, l_new, acc_new, ik + 1), None

        init = (
            jnp.full((B, Hq, block_q, 1), -1e30, jnp.float32),
            jnp.zeros((B, Hq, block_q, 1), jnp.float32),
            jnp.zeros((B, Hq, block_q, D), jnp.float32),
            jnp.asarray(0, jnp.int32),
        )
        (m, l, acc, _), _ = jax.lax.scan(
            kv_step, init, (k_pre.transpose(2, 0, 1, 3, 4), v_pre.transpose(2, 0, 1, 3, 4))
        )
        outs.append(acc / jnp.maximum(l, 1e-30))
    out = jnp.concatenate(outs, axis=2)                          # (B,H,S,D)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def rwkv6_chunked(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
    u: jax.Array, state: jax.Array, *, chunk: int = 64,
):
    """Chunked WKV6 in pure jnp — same math as kernels/rwkv6_scan.py.
    scan over T/chunk steps carrying the (B, H, K, V) state."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def resh(x, d):
        return x.reshape(B, nc, chunk, H, d).transpose(1, 0, 3, 2, 4)  # (nc,B,H,C,d)

    rc, kc, wc = resh(rf, K), resh(kf, K), resh(wf, K)
    vc = resh(vf, V)

    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (t_idx > s_idx)[..., None]
    diag = (t_idx == s_idx)

    def step(s, inp):
        rb, kb, vb, wb = inp                       # (B,H,C,K/V)
        logdec = -jnp.exp(wb)
        cum = jnp.cumsum(logdec, axis=2)
        cum_excl = cum - logdec
        o_inter = jnp.einsum("bhck,bhkv->bhcv", rb * jnp.exp(cum_excl), s)
        diff = cum_excl[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,H,C,C,K)
        gate = jnp.where(strict[None, None], jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        A = jnp.einsum("bhtk,bhsk,bhtsk->bhts", rb, kb, gate)
        A_diag = jnp.sum(rb * uf[None, :, None, :] * kb, axis=-1)      # (B,H,C)
        A = A + jnp.where(diag[None, None], A_diag[:, :, :, None], 0.0)
        o_intra = jnp.einsum("bhts,bhsv->bhtv", A, vb)
        total = cum[:, :, -1]                      # (B,H,K)
        k_scaled = kb * jnp.exp(jnp.minimum(total[:, :, None, :] - cum, 0.0))
        s_new = jnp.exp(total)[..., None] * s + jnp.einsum("bhck,bhcv->bhkv", k_scaled, vb)
        return s_new, o_inter + o_intra

    s_final, outs = jax.lax.scan(step, state.astype(jnp.float32), (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, V)
    return out.astype(r.dtype), s_final.astype(state.dtype)


def rwkv6_decode_step(r, k, v, w, u, state):
    """Single-token WKV6 step. r/k/w: (B,H,K); v: (B,H,V); state (B,H,K,V)."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    sf = state.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", rf, sf + uf[None, :, :, None] * kv)
    s_new = jnp.exp(-jnp.exp(wf))[..., None] * sf + kv
    return o.astype(r.dtype), s_new.astype(state.dtype)


def mamba2_ssd_chunked(
    x: jax.Array, dt: jax.Array, A: jax.Array,
    Bm: jax.Array, C: jax.Array, state: jax.Array, *, chunk: int = 64,
):
    """Chunked SSD in pure jnp — same math as kernels/mamba2_ssd.py."""
    B, T, H, Pd = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    xf = x.astype(jnp.float32).reshape(B, nc, chunk, H, Pd).transpose(1, 0, 3, 2, 4)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)
    Bf = Bm.astype(jnp.float32).reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    Cf = C.astype(jnp.float32).reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    Af = A.astype(jnp.float32)

    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lower = t_idx >= s_idx

    def step(s, inp):
        xb, dtb, Bb, Cb = inp          # (B,H,C,P),(B,H,C),(B,C,N),(B,C,N)
        cum = jnp.cumsum(dtb * Af[None, :, None], axis=2)       # (B,H,C)
        dmat = jnp.where(
            lower[None, None], jnp.exp(jnp.minimum(cum[:, :, :, None] - cum[:, :, None, :], 0.0)), 0.0
        )                                                        # (B,H,C,C)
        cb = jnp.einsum("btn,bsn->bts", Cb, Bb)                  # (B,C,C)
        G = cb[:, None] * dmat * dtb[:, :, None, :]              # (B,H,C,C)
        y_intra = jnp.einsum("bhts,bhsp->bhtp", G, xb)
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum("btn,bhpn->bhtp", Cb, s)
        total = cum[:, :, -1]                                    # (B,H)
        xw = xb * (dtb * jnp.exp(jnp.minimum(total[:, :, None] - cum, 0.0)))[..., None]
        s_new = jnp.exp(total)[..., None, None] * s + jnp.einsum(
            "bhcp,bcn->bhpn", xw, Bb
        )
        return s_new, y_intra + y_inter

    s_final, ys = jax.lax.scan(step, state.astype(jnp.float32), (xf, dtf, Bf, Cf))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, H, Pd)
    return y.astype(x.dtype), s_final.astype(state.dtype)


def mamba2_decode_step(x, dt, A, Bm, C, state):
    """Single-token SSD step. x: (B,H,P); dt: (B,H); Bm/C: (B,N)."""
    xf, dtf, Bf, Cf = (a.astype(jnp.float32) for a in (x, dt, Bm, C))
    Af = A.astype(jnp.float32)
    sf = state.astype(jnp.float32)
    decay = jnp.exp(dtf * Af[None, :])
    upd = (dtf[..., None] * xf)[..., None] * Bf[:, None, None, :]
    s_new = decay[..., None, None] * sf + upd
    y = jnp.einsum("bhpn,bn->bhp", s_new, Cf)
    return y.astype(x.dtype), s_new.astype(state.dtype)


# --------------------------------------------------------------------------
# Mamba2 SSD recurrence (scalar decay per head)
# --------------------------------------------------------------------------
def mamba2_ssd_ref(
    x: jax.Array,      # (B, T, H, P)
    dt: jax.Array,     # (B, T, H) — already softplus'd, > 0
    A: jax.Array,      # (H,) — negative
    Bm: jax.Array,     # (B, T, N) — input matrix (single group)
    C: jax.Array,      # (B, T, N) — output matrix (single group)
    state: jax.Array,  # (B, H, P, N)
):
    """Exact sequential SSD recurrence.

        S_t = exp(dt_t A_h) S_{t-1} + dt_t x_t ⊗ B_t
        y_t = S_t C_t
    """
    xf, dtf, Bf, Cf = (a.astype(jnp.float32) for a in (x, dt, Bm, C))
    Af = A.astype(jnp.float32)
    s0 = state.astype(jnp.float32)

    def step(s, inp):
        xt, dtt, Bt, Ct = inp  # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * Af[None, :])                  # (B,H)
        upd = (dtt[..., None] * xt)[..., None] * Bt[:, None, None, :]  # (B,H,P,N)
        s_new = decay[..., None, None] * s + upd
        y = jnp.einsum("bhpn,bn->bhp", s_new, Ct)
        return s_new, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    s_final, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, T, H, P)
    return y.astype(x.dtype), s_final.astype(state.dtype)
