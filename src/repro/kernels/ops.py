"""Jit'd dispatchers over kernel implementations.

``impl`` selects:
  * ``reference``        — pure-jnp oracle (ref.py). XLA-fused; the CPU
                           dry-run / default model path.
  * ``pallas``           — the Pallas TPU kernel (TARGET hardware).
  * ``pallas_interpret`` — the same kernel body executed in interpret mode
                           (CPU correctness validation; used by tests).

Every dispatcher here is single-device; the mesh-sharded twins (shard-local
launch of the SAME kernels + cheap cross-device merges) live in
``repro.kernels.shard_ops`` and are selected by the Forest/Retriever when a
serve mesh is attached (``Forest.set_mesh``). mesh=None callers never touch
that module — the single-device path below stays byte-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.browse_scores import browse_scores as _browse
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.topk_sim import topk_sim as _topk
from repro.kernels.tree_refresh import tree_refresh as _tree_refresh
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6
from repro.kernels.mamba2_ssd import mamba2_ssd as _ssd

VALID_IMPLS = ("reference", "pallas", "pallas_interpret")


def _check(impl: str) -> None:
    if impl not in VALID_IMPLS:
        raise ValueError(f"impl must be one of {VALID_IMPLS}, got {impl!r}")


@functools.partial(jax.jit, static_argnames=("causal", "impl", "block_q", "block_kv"))
def attention(q, k, v, *, causal=True, impl="reference", block_q=512, block_kv=512):
    _check(impl)
    if impl == "reference":
        return _ref.attention_ref(q, k, v, causal=causal)
    return _flash(
        q, k, v,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
        interpret=(impl == "pallas_interpret"),
    )


@functools.partial(jax.jit, static_argnames=("impl", "block_kv"))
def decode_attention(q, k_cache, v_cache, lengths, *, impl="reference", block_kv=1024):
    _check(impl)
    if impl == "reference":
        return _ref.decode_attention_ref(q, k_cache, v_cache, lengths)
    return _decode(
        q, k_cache, v_cache, lengths,
        block_kv=block_kv,
        interpret=(impl == "pallas_interpret"),
    )


@functools.partial(jax.jit, static_argnames=("k", "normalize", "impl"))
def topk_sim(queries, keys, k, *, normalize=True, num_valid=None, impl="reference"):
    _check(impl)
    if impl == "reference":
        return _ref.topk_sim_ref(queries, keys, k, normalize=normalize,
                                 num_valid=num_valid)
    return _topk(
        queries, keys, k,
        normalize=normalize,
        num_valid=num_valid,
        interpret=(impl == "pallas_interpret"),
    )


@functools.partial(jax.jit, static_argnames=("impl",))
def browse_scores(child_emb, q_emb, child_mask, *, impl="reference"):
    """One browse depth level: per-frontier-entry masked child scoring.
    child_emb (F, K, D), q_emb (F, D), child_mask (F, K) -> (F, K) f32."""
    _check(impl)
    if impl == "reference":
        return _ref.browse_scores_ref(child_emb, q_emb, child_mask)
    return _browse(
        child_emb, q_emb, child_mask, interpret=(impl == "pallas_interpret")
    )


# ---------------------------------------------------------------------------
# device-resident index maintenance (used by Forest's normalized index cache)
# ---------------------------------------------------------------------------
@jax.jit
def normalize_rows(x):
    """L2-normalize rows with the same formula topk_sim uses in-kernel, so a
    pre-normalized device index + ``normalize=False`` is numerically
    equivalent to passing the raw matrix with ``normalize=True``."""
    xf = x.astype(jnp.float32)
    return xf / (jnp.linalg.norm(xf, axis=-1, keepdims=True) + 1e-6)


@jax.jit
def scatter_normalize_rows(arr, idx, rows):
    """Incremental device-index update: write normalized ``rows`` at ``idx``
    in the cached matrix. Padding entries carry idx == arr.shape[0] (out of
    bounds) and are dropped, so callers can bucket the update size. ``arr``
    is deliberately NOT donated: previously returned index views must stay
    valid after a later sync (donation would delete their buffer on
    accelerator backends)."""
    rf = rows.astype(jnp.float32)
    rf = rf / (jnp.linalg.norm(rf, axis=-1, keepdims=True) + 1e-6)
    return arr.at[idx].set(rf, mode="drop")


@functools.partial(jax.jit, static_argnames=("add",))
def grow_rows(arr, add):
    """Geometric device-cache growth (single-device path): append ``add``
    zero rows to a cached index matrix ON DEVICE. Capacity growth used to
    invalidate the whole cache and re-upload + re-normalize every row from
    host; this keeps the existing normalized rows in place so only new/dirty
    rows transfer (Forest._sync_device). Not donated, for the same
    view-validity reason as scatter_normalize_rows."""
    return jnp.concatenate(
        [arr, jnp.zeros((add, arr.shape[1]), arr.dtype)])


@functools.partial(jax.jit, static_argnames=("keep",))
def _shrink_rows(arr, keep):
    """Copy rows [0, keep) into a fresh (smaller) buffer so the oversized
    arena can be deleted. Callers bucket ``keep`` (power of two) to bound the
    jit-compile set, mirroring grow_rows' geometric policy."""
    return jnp.array(arr[:keep])


def _delete_buffer(arr) -> None:
    """Eagerly free a device buffer. Dropping the Python reference leaves
    the buffer alive until GC runs; at residency-eviction rates that is
    exactly the device-memory leak the hot budget exists to prevent."""
    delete = getattr(arr, "delete", None)
    if delete is None:
        return
    try:
        delete()
    except Exception:
        pass    # already deleted / backend without explicit free


def release_rows(arr, keep: int = 0):
    """Inverse of grow_rows: release device rows held by a cached index.

    ``keep=0`` (tenant demotion) frees the whole buffer eagerly and returns
    None — the caller drops its reference and the next index access is a
    fresh upload. ``keep=n`` shrinks the geometric-growth arena: rows
    [0, n) move into a fresh buffer (materialized before the old one is
    deleted), the oversized arena is freed, and the shrunk buffer is
    returned. Not jitted end-to-end: the delete is a host-side buffer
    operation, so only the copy is compiled (``_shrink_rows``)."""
    if arr is None:
        return None
    if keep <= 0:
        _delete_buffer(arr)
        return None
    out = _shrink_rows(arr, keep)
    jax.block_until_ready(out)
    _delete_buffer(arr)
    return out


@functools.partial(jax.jit, static_argnames=("impl",))
def tree_refresh(child_emb, child_mask, *, impl="reference"):
    _check(impl)
    if impl == "reference":
        return _ref.tree_refresh_ref(child_emb, child_mask)
    return _tree_refresh(
        child_emb, child_mask, interpret=(impl == "pallas_interpret")
    )


@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def rwkv6_scan(r, k, v, w, u, state, *, impl="reference", chunk=64):
    _check(impl)
    if impl == "reference":
        return _ref.rwkv6_scan_ref(r, k, v, w, u, state)
    return _rwkv6(
        r, k, v, w, u, state,
        chunk=chunk,
        interpret=(impl == "pallas_interpret"),
    )


@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def mamba2_ssd(x, dt, A, Bm, C, state, *, impl="reference", chunk=64):
    _check(impl)
    if impl == "reference":
        return _ref.mamba2_ssd_ref(x, dt, A, Bm, C, state)
    return _ssd(
        x, dt, A, Bm, C, state,
        chunk=chunk,
        interpret=(impl == "pallas_interpret"),
    )
