"""Fused cosine-similarity + top-k — Pallas TPU kernel.

This is MemForest's retrieval hot path: forest recall scores a query against
all tree-root embeddings; fact-to-tree recall scores it against the canonical
fact index. Fusing normalize + matmul + running top-k selection avoids ever
materializing the full (Q, N) score matrix in HBM — the kernel streams key
tiles through VMEM and keeps a (block_q, K) running top-k in scratch.

Grid: (num_q_blocks, num_key_blocks), key blocks innermost/sequential.
Selection: per key tile, the candidate pool is [running top-k | tile scores]
(block_q, K + block_kv); K iterations of max+mask extract the new top-k.
K <= 32 keeps this cheap relative to the (block_q x D x block_kv) MXU matmul.

Tie-break contract: results are ordered by (score desc, key index asc). The
argmax-based selection realizes this for free — within the candidate pool the
running top-k (lower global indices, ascending among equal scores) precedes
the tile columns (ascending), and argmax returns the FIRST maximum. The
reference oracle and the cross-shard candidate merge (:func:`merge_topk`)
implement the same order explicitly, so single-device and mesh-sharded
retrieval are exactly result-identical, not tie-lucky.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def _topk_kernel(
    nv_ref,                # (1, 1) int32 — number of valid keys (runtime)
    q_ref,                 # (bq, D) — pre-normalized
    k_ref,                 # (bk, D) — pre-normalized
    vals_ref, idx_ref,     # (bq, K) f32 / int32 outputs
    tv_ref, ti_ref,        # scratch: (bq, K) f32 / int32 running top-k
    *,
    k: int,
    block_kv: int,
    num_kv_blocks: int,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        tv_ref[...] = jnp.full_like(tv_ref, NEG_INF)
        ti_ref[...] = jnp.full_like(ti_ref, -1)

    q = q_ref[...].astype(jnp.float32)
    kk = k_ref[...].astype(jnp.float32)
    scores = jax.lax.dot_general(
        q, kk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)
    base = ik * block_kv
    cols = base + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(cols < nv_ref[0, 0], scores, NEG_INF)  # mask padded keys

    # candidate pool = running top-k ++ this tile
    pool_v = jnp.concatenate([tv_ref[...], scores], axis=1)         # (bq, K+bk)
    pool_i = jnp.concatenate([ti_ref[...], cols], axis=1)

    new_v = []
    new_i = []
    for _ in range(k):
        m = jnp.max(pool_v, axis=1, keepdims=True)                   # (bq, 1)
        am = jnp.argmax(pool_v, axis=1)                              # (bq,)
        sel = jnp.take_along_axis(pool_i, am[:, None], axis=1)       # (bq, 1)
        new_v.append(m)
        new_i.append(sel)
        onehot = jax.lax.broadcasted_iota(jnp.int32, pool_v.shape, 1) == am[:, None]
        pool_v = jnp.where(onehot, NEG_INF, pool_v)
    tv_ref[...] = jnp.concatenate(new_v, axis=1)
    ti_ref[...] = jnp.concatenate(new_i, axis=1)

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        vals_ref[...] = tv_ref[...]
        idx_ref[...] = jnp.where(tv_ref[...] > NEG_INF / 2, ti_ref[...], -1)


def merge_topk(vals: jax.Array, idx: jax.Array, k: int):
    """Deterministic top-k over a candidate pool: (Q, C) scores + global key
    indices -> (Q, k) ordered by (score desc, index asc). Dead candidates
    carry vals == NEG_INF / idx == -1 and sort last; surviving dead slots are
    re-masked to idx -1 (matches the kernel/oracle contract).

    This is the cross-device reduction of the mesh-sharded scan
    (kernels/shard_ops.py): each shard contributes its local top-k as
    (score, global row) candidates and the merge is a cheap (Q, S*k)
    two-key sort — never the full (Q, N) score matrix."""
    neg = -vals
    sneg, sidx = jax.lax.sort((neg, idx), dimension=-1, num_keys=2)
    out_v = -sneg[..., :k]
    out_i = sidx[..., :k]
    return out_v, jnp.where(out_v > NEG_INF / 2, out_i, -1)


def _pad_to(x: jax.Array, n: int, axis: int = 0) -> jax.Array:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def topk_sim(
    queries: jax.Array,  # (Q, D)
    keys: jax.Array,     # (N, D)
    k: int,
    *,
    normalize: bool = True,
    num_valid=None,      # optional traced scalar (defaults to N)
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
):
    Q, D = queries.shape
    N = keys.shape[0]
    qf = queries.astype(jnp.float32)
    kf = keys.astype(jnp.float32)
    if normalize:
        qf = qf / (jnp.linalg.norm(qf, axis=-1, keepdims=True) + 1e-6)
        kf = kf / (jnp.linalg.norm(kf, axis=-1, keepdims=True) + 1e-6)

    block_q = min(block_q, max(Q, 8))
    block_kv = min(block_kv, max(N, 8))
    Qp = -(-Q // block_q) * block_q
    Np = -(-N // block_kv) * block_kv
    qp = _pad_to(qf, Qp)
    kp = _pad_to(kf, Np)
    nq = Qp // block_q
    nkv = Np // block_kv
    nv = jnp.asarray(N if num_valid is None else num_valid, jnp.int32).reshape(1, 1)

    kernel = functools.partial(
        _topk_kernel,
        k=k,
        block_kv=block_kv,
        num_kv_blocks=nkv,
    )
    vals, idx = pl.pallas_call(
        kernel,
        grid=(nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1), lambda iq, ik: (0, 0)),
            pl.BlockSpec((block_q, D), lambda iq, ik: (iq, 0)),
            pl.BlockSpec((block_kv, D), lambda iq, ik: (ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda iq, ik: (iq, 0)),
            pl.BlockSpec((block_q, k), lambda iq, ik: (iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(nv, qp, kp)
    return vals[:Q], idx[:Q]
