"""Level-parallel MemTree summary refresh — Pallas TPU kernel.

One level of the paper's lazy dirty-path flush (Algorithm 1, lines 10-18):
every dirty parent at a level aggregates its (<= k) children's embeddings
into a normalized interval summary. The host gathers child embeddings into a
padded (P, K, D) tensor (P = dirty parents at this level, K = branching
factor); the kernel computes the masked mean + l2 normalization for a whole
block of parents at once — the paper's thread-pool parallelism becomes one
vectorized VPU pass.

Grid: (num_parent_blocks,). Block = (block_p, K, D): with block_p = 8,
K = 16, D = 256 the tile is 128 KB fp32 — trivially VMEM-resident, and the
reduction axis K is unrolled so the lanes dimension stays D (128-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_BLOCK_P = 8


def _refresh_kernel(emb_ref, mask_ref, out_ref):
    emb = emb_ref[...].astype(jnp.float32)    # (bp, K, D)
    m = mask_ref[...].astype(jnp.float32)     # (bp, K)
    s = jnp.sum(emb * m[..., None], axis=1)   # (bp, D)
    cnt = jnp.maximum(jnp.sum(m, axis=1, keepdims=True), 1.0)
    mean = s / cnt
    norm = jnp.sqrt(jnp.sum(mean * mean, axis=-1, keepdims=True)) + 1e-6
    out_ref[...] = (mean / norm).astype(out_ref.dtype)


def tree_refresh(
    child_emb: jax.Array,   # (P, K, D)
    child_mask: jax.Array,  # (P, K)
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool = False,
) -> jax.Array:
    P, K, D = child_emb.shape
    block_p = min(block_p, P)
    Pp = -(-P // block_p) * block_p
    if Pp != P:
        child_emb = jnp.pad(child_emb, ((0, Pp - P), (0, 0), (0, 0)))
        child_mask = jnp.pad(child_mask, ((0, Pp - P), (0, 0)))
    mask_f = child_mask.astype(jnp.float32)

    out = pl.pallas_call(
        _refresh_kernel,
        grid=(Pp // block_p,),
        in_specs=[
            pl.BlockSpec((block_p, K, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_p, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_p, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Pp, D), child_emb.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(child_emb, mask_f)
    return out[:P]
