"""Level-synchronous browse scoring — Pallas TPU kernel.

One depth level of the batched tree browse (read path): every frontier entry
(query, tree, beam-node) scores its (<= K) packed child embeddings against
that entry's OWN query vector. The host packs the whole batch's frontiers
into a padded (F, K, D) child tensor + (F, D) query tensor; the kernel
computes the masked per-row matvec for a whole block of frontier entries in
one VPU pass — the read-path twin of ``tree_refresh``'s cross-tree batch
dimension.

Grid: (num_frontier_blocks,). Block = (block_f, K, D): with block_f = 64,
K = 8, D = 256 the tile is 512 KB fp32 — VMEM-resident; the reduction axis
is D (lanes stay 128-aligned), K is a small unrolled sublane dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

DEFAULT_BLOCK_F = 64


def _browse_kernel(emb_ref, q_ref, mask_ref, out_ref):
    emb = emb_ref[...].astype(jnp.float32)    # (bf, K, D)
    q = q_ref[...].astype(jnp.float32)        # (bf, D)
    m = mask_ref[...].astype(jnp.float32)     # (bf, K)
    s = jnp.sum(emb * q[:, None, :], axis=-1)  # (bf, K)
    out_ref[...] = (s * m).astype(out_ref.dtype)


def browse_scores(
    child_emb: jax.Array,   # (F, K, D) packed frontier children
    q_emb: jax.Array,       # (F, D) per-entry query vector
    child_mask: jax.Array,  # (F, K) 1.0 for real child slots
    *,
    block_f: int = DEFAULT_BLOCK_F,
    interpret: bool = False,
) -> jax.Array:
    F, K, D = child_emb.shape
    block_f = min(block_f, F)
    Fp = -(-F // block_f) * block_f
    if Fp != F:
        child_emb = jnp.pad(child_emb, ((0, Fp - F), (0, 0), (0, 0)))
        q_emb = jnp.pad(q_emb, ((0, Fp - F), (0, 0)))
        child_mask = jnp.pad(child_mask, ((0, Fp - F), (0, 0)))
    mask_f = child_mask.astype(jnp.float32)

    out = pl.pallas_call(
        _browse_kernel,
        grid=(Fp // block_f,),
        in_specs=[
            pl.BlockSpec((block_f, K, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_f, D), lambda i: (i, 0)),
            pl.BlockSpec((block_f, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_f, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Fp, K), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(child_emb, q_emb, mask_f)
    return out[:F]
