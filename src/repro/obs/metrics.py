"""Metric primitives: counters, gauges, and streaming-quantile latency
histograms behind one :class:`MetricsRegistry`.

Design constraints (ROADMAP: "mixed-load latency accounting"):

  * **always-on and cheap** — counters back the legacy ``metrics()`` dicts
    of the serve engine / maintenance plane / residency manager, so an
    increment must cost a couple of attribute ops, nothing more;
  * **streaming quantiles** — latency distributions are recorded into
    log-spaced buckets (HDR-histogram style): fixed memory, O(1) record,
    bounded *relative* error on any quantile (half a bucket width,
    ``GROWTH**0.5 - 1`` ≈ 2.5%), which is what p50/p99 tuning needs;
  * **no hard dependencies** — pure Python + ``math``, importable before
    jax/numpy land.

Naming scheme (see README "Observability"): metric names are
``<component>/<what>`` — ``serve/ingest_sessions``,
``maintenance/units_run``, ``residency/evictions``, ``journal/appends`` —
and span-duration histograms are ``span/<span name>``
(``span/engine.decode``, ``span/forest.flush``, ...), all in seconds.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence


class Counter:
    """Monotonic (float-friendly) counter. ``value`` is the read API.

    ``inc`` takes a per-instance lock: ``self.value += n`` is a read-
    modify-write that the GIL does NOT make atomic (the interpreter can
    switch threads between the load and the store), and counters are
    incremented from the serve thread and the maintenance worker at once.
    """

    __slots__ = ("value", "_lock")

    def __init__(self) -> None:
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n=1) -> None:
        with self._lock:
            self.value += n

    def set(self, v) -> None:
        self.value = v


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def set(self, v) -> None:
        self.value = v


class LatencyHistogram:
    """Streaming latency distribution with log-spaced buckets.

    Bucket ``i`` covers ``[MIN * GROWTH**(i-1), MIN * GROWTH**i)`` (bucket 0
    holds everything below ``MIN``); a quantile is reported as the geometric
    midpoint of its bucket, so the relative error of any reported quantile
    is at most ``GROWTH**0.5 - 1`` (≈2.5% at the default 5% growth) —
    verified against exact sorting in tests/test_obs.py.
    """

    MIN = 1e-7                      # 0.1 µs — everything below lands in bucket 0
    GROWTH = 1.05
    _BUCKETS = 1 + int(math.log(1e4 / MIN) / math.log(GROWTH)) + 1   # ..1e4 s

    __slots__ = ("count", "sum", "max", "_b", "_inv_log_growth", "_lock")

    def __init__(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.max = 0.0
        self._b: List[int] = [0] * self._BUCKETS
        self._inv_log_growth = 1.0 / math.log(self.GROWTH)
        # record() updates four fields; without the lock a thread switch
        # mid-update loses counts or leaves count/sum inconsistent
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        if seconds < self.MIN:
            idx = 0
        else:
            idx = 1 + int(math.log(seconds / self.MIN) * self._inv_log_growth)
            if idx >= len(self._b):
                idx = len(self._b) - 1
        with self._lock:
            self.count += 1
            self.sum += seconds
            if seconds > self.max:
                self.max = seconds
            self._b[idx] += 1

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (q in [0, 1]) in seconds; 0.0 when empty."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for i, c in enumerate(self._b):
            seen += c
            if seen >= rank:
                if i == 0:
                    return self.MIN / 2
                # geometric midpoint of [MIN*G**(i-1), MIN*G**i)
                return self.MIN * self.GROWTH ** (i - 0.5)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum_s": self.sum,
            "mean_s": self.mean,
            "p50_s": self.quantile(0.50),
            "p90_s": self.quantile(0.90),
            "p99_s": self.quantile(0.99),
            "max_s": self.max,
        }


class MetricsRegistry:
    """Get-or-create registry of named counters / gauges / histograms.

    Creation takes a lock (components register from serve + maintenance
    threads); the returned objects are then held by the caller and updated
    lock-free — single attribute ops under the GIL, and every current
    writer already runs under its component's own lock where it matters.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, LatencyHistogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str) -> LatencyHistogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, LatencyHistogram())
        return h

    # ------------------------------------------------------------------
    # Read methods copy the name->object dicts under the registration lock
    # before iterating: the maintenance worker registers metrics lazily, so
    # a lock-free iteration from the serve thread can hit "dict changed
    # size during iteration" mid-snapshot.
    def counters(self) -> Dict[str, float]:
        with self._lock:
            items = sorted(self._counters.items())
        return {k: c.value for k, c in items}

    def histograms(self) -> Dict[str, LatencyHistogram]:
        with self._lock:
            return dict(self._hists)

    def snapshot(self) -> Dict[str, float]:
        """One flat dict of everything: counters and gauges by name,
        histograms expanded to ``<name>/{count,mean_s,p50_s,p90_s,p99_s}``."""
        with self._lock:
            gauges = sorted(self._gauges.items())
            hists = sorted(self._hists.items())
        out: Dict[str, float] = {}
        out.update(self.counters())
        for k, g in gauges:
            out[k] = g.value
        for k, h in hists:
            for stat, v in h.summary().items():
                out[f"{k}/{stat}"] = v
        return out

    def latency_summary(self, prefix: str = "span/") -> Dict[str, Dict[str, float]]:
        """Per-histogram summaries for names under ``prefix`` (default: the
        span-duration histograms) — the per-phase p50/p99 table the mixed
        serving benchmark emits."""
        with self._lock:
            hists = sorted(self._hists.items())
        return {k[len(prefix):]: h.summary()
                for k, h in hists if k.startswith(prefix) and h.count}


def percentiles(samples: Sequence[float],
                qs: Sequence[float] = (0.50, 0.90, 0.99)) -> Dict[str, float]:
    """Exact percentiles of a finite sample (nearest-rank with linear
    interpolation) — the reference the histogram accuracy test compares
    against, shared with benchmarks/common.py."""
    if not samples:
        return {f"p{int(q * 100)}": 0.0 for q in qs}
    s = sorted(samples)
    out = {}
    for q in qs:
        pos = q * (len(s) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        out[f"p{int(q * 100)}"] = s[lo] + (s[hi] - s[lo]) * (pos - lo)
    return out
