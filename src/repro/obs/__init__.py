"""Low-overhead observability for the serve loop (ISSUE 9 tentpole).

One :class:`Observability` handle bundles a :class:`MetricsRegistry`
(counters / gauges / streaming-quantile latency histograms) with a span
tracer. Components (ServeEngine, Forest, MaintenancePlane,
ResidencyManager, DurableMemForest) each own a handle — their legacy
``metrics()`` dicts now read through the registry — and all handles share
the process-global tracer unless given a private one, so::

    from repro import obs
    sink = obs.JsonlSink("trace.jsonl")
    obs.enable_tracing(sink)          # every span site in the process
    ... serve traffic ...
    obs.disable_tracing()             # flushes the sink
    sink.close()

Costs: registry counters are always on (a couple of attribute ops — they
ARE the metrics dicts). Span sites pay one boolean check + a shared no-op
singleton while tracing is disabled; the mixed serving benchmark
(benchmarks/bench_serving_mixed.py) measures that tax on the B=16 ingest
and B=32 query benches and asserts it stays ≤2%.
"""
from __future__ import annotations

from typing import Optional

from repro.obs import trace as _trace
from repro.obs.metrics import (Counter, Gauge, LatencyHistogram,
                               MetricsRegistry, percentiles)
from repro.obs.trace import (GLOBAL, NULL_SPAN, JsonlSink, MemorySink, Span,
                             Tracer, read_trace)

__all__ = [
    "Observability", "MetricsRegistry", "Counter", "Gauge",
    "LatencyHistogram", "percentiles", "Tracer", "Span", "JsonlSink",
    "MemorySink", "NULL_SPAN", "enable_tracing", "disable_tracing",
    "tracing_enabled", "read_trace", "get_obs",
]


class Observability:
    """A component's handle: its metric registry + a tracer reference.

    ``tracer=None`` (the default) resolves to the process-global tracer at
    every call, so flipping :func:`enable_tracing` reaches components
    created long before it."""

    __slots__ = ("registry", "_tracer")

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._tracer = tracer

    # -- tracing -----------------------------------------------------------
    @property
    def tracer(self) -> Tracer:
        return self._tracer if self._tracer is not None else _trace.GLOBAL

    @property
    def enabled(self) -> bool:
        """True when span tracing is live (metrics are always live)."""
        return (self._tracer or _trace.GLOBAL).enabled

    def span(self, name: str, **attrs):
        """Context-manager timer. While tracing is disabled this returns
        the shared no-op span — the only cost hot paths ever pay."""
        tr = self._tracer if self._tracer is not None else _trace.GLOBAL
        if not tr.enabled:
            return NULL_SPAN
        return Span(tr, name, self.registry, attrs or None)

    def event(self, name: str, **attrs) -> None:
        """Point event under the calling thread's current span."""
        tr = self._tracer if self._tracer is not None else _trace.GLOBAL
        if tr.enabled:
            tr.event(name, attrs or None)

    # -- metrics (registry delegates) --------------------------------------
    def counter(self, name: str) -> Counter:
        return self.registry.counter(name)

    def gauge(self, name: str) -> Gauge:
        return self.registry.gauge(name)

    def histogram(self, name: str) -> LatencyHistogram:
        return self.registry.histogram(name)


def get_obs(obs: Optional[Observability]) -> Observability:
    """``obs or Observability()`` with a stable spelling for components."""
    return obs if obs is not None else Observability()


def enable_tracing(sink=None) -> Tracer:
    """Turn on the process-global tracer (optionally with a sink — a
    :class:`JsonlSink`, :class:`MemorySink`, or anything with
    ``write(dict)``/``flush()``). Returns the tracer."""
    return _trace.GLOBAL.enable(sink)


def disable_tracing() -> None:
    """Turn span tracing back into the no-op backend (flushes the sink)."""
    _trace.GLOBAL.disable()


def tracing_enabled() -> bool:
    return _trace.GLOBAL.enabled
