"""Span tracing: context-manager timers with nesting, attributes, point
events, a JSONL sink, and a no-op backend that hot paths can afford.

A :class:`Tracer` owns the enabled flag, the (optional) sink, and a
thread-local span stack; the module-global :data:`GLOBAL` tracer is what
every component uses unless explicitly handed another one, so
``obs.enable_tracing()`` lights up the whole process — serve engine,
forest flush, journal, residency — in one call.

Disabled cost: ``Observability.span()`` (repro/obs/__init__.py) checks one
boolean and returns the shared :data:`NULL_SPAN` singleton — no
allocation, no clock read, no stack push. The mixed serving benchmark
measures this and asserts the instrumentation tax on the ingest/query
benches stays ≤2% when tracing is off.

Enabled cost per span: two ``perf_counter`` reads, a stack push/pop, one
histogram record (into the owning component's registry, name
``span/<name>``), and — only when a sink is attached — one JSONL line.

Trace format (one JSON object per line)::

    {"kind": "span",  "name": "engine.decode", "span": 7, "parent": 5,
     "ts": 0.01324, "dur_s": 0.00211, "attrs": {...}}
    {"kind": "event", "name": "durability/journal:append", "span": 7,
     "ts": 0.01388, "attrs": {...}}

``ts`` is seconds since the tracer was enabled (monotonic clock), so
records from one process order and nest exactly; a span line is written
when the span *closes*, so child spans and interior events appear before
their parent — reconstruct the tree via ``span``/``parent`` ids, order by
``ts``.
"""
from __future__ import annotations

import json
import threading
from time import perf_counter
from typing import Any, Dict, List, Optional


class _NullSpan:
    """The no-op backend: a single shared instance stands in for every span
    while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self

    def event(self, name: str, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class JsonlSink:
    """Append trace records to a JSONL file. Buffered; ``close()`` (or the
    context manager) flushes."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")
        self.records_written = 0

    def write(self, rec: Dict[str, Any]) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self.records_written += 1

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class MemorySink:
    """In-memory sink (tests, benchmarks): records land in ``records``."""

    def __init__(self) -> None:
        self.records: List[Dict[str, Any]] = []

    def write(self, rec: Dict[str, Any]) -> None:
        self.records.append(rec)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass

    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["kind"] == "span"
                and (name is None or r["name"] == name)]

    def events(self, prefix: str = "") -> List[Dict[str, Any]]:
        return [r for r in self.records if r["kind"] == "event"
                and r["name"].startswith(prefix)]


class Span:
    """One timed, attributed, nestable region. Use via
    ``Observability.span(name, **attrs)`` as a context manager; on exit the
    duration is recorded into the owning registry's ``span/<name>``
    histogram and (if a sink is attached) a JSONL line is emitted."""

    __slots__ = ("tracer", "registry", "name", "attrs", "span_id",
                 "parent_id", "t_start", "dur_s")

    def __init__(self, tracer: "Tracer", name: str, registry, attrs):
        self.tracer = tracer
        self.registry = registry
        self.name = name
        self.attrs = attrs
        self.span_id = 0
        self.parent_id: Optional[int] = None
        self.t_start = 0.0
        self.dur_s = 0.0

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes after the span opened (e.g. counts
        known only at the end of the region)."""
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs) -> "Span":
        """Point event stamped inside this span."""
        self.tracer._emit_event(name, self.span_id, attrs)
        return self

    def __enter__(self) -> "Span":
        tr = self.tracer
        self.span_id = tr._next_id()
        stack = tr._stack()
        self.parent_id = stack[-1].span_id if stack else None
        stack.append(self)
        self.t_start = perf_counter() - tr.t0
        return self

    def __exit__(self, *exc) -> bool:
        self.dur_s = perf_counter() - self.tracer.t0 - self.t_start
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:                     # tolerate exotic unwinds
            stack.remove(self)
        if self.registry is not None:
            self.registry.histogram("span/" + self.name).record(self.dur_s)
        sink = self.tracer.sink
        if sink is not None:
            sink.write({"kind": "span", "name": self.name,
                        "span": self.span_id, "parent": self.parent_id,
                        "ts": self.t_start, "dur_s": self.dur_s,
                        "attrs": self.attrs or {}})
        return False


class Tracer:
    """Enabled flag + sink + id allocator + per-thread span stack."""

    def __init__(self, sink=None, enabled: bool = False):
        self.enabled = enabled
        self.sink = sink
        self.t0 = perf_counter()
        self._id = 0
        self._id_lock = threading.Lock()
        self._tls = threading.local()

    # -- plumbing ----------------------------------------------------------
    def _next_id(self) -> int:
        with self._id_lock:
            self._id += 1
            return self._id

    def _stack(self) -> List[Span]:
        s = getattr(self._tls, "stack", None)
        if s is None:
            s = self._tls.stack = []
        return s

    def current_span(self) -> Optional[Span]:
        s = self._stack()
        return s[-1] if s else None

    # -- record construction ----------------------------------------------
    def span(self, name: str, registry=None, attrs=None):
        """Start (unentered) a span; returns NULL_SPAN while disabled."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, registry, attrs)

    def event(self, name: str, attrs=None) -> None:
        """Point event attached to the calling thread's current span."""
        if not self.enabled:
            return
        cur = self.current_span()
        self._emit_event(name, cur.span_id if cur else None, attrs)

    def _emit_event(self, name: str, span_id, attrs) -> None:
        # capture: disable() on another thread nulls self.sink between the
        # check and the write otherwise
        sink = self.sink
        if sink is not None:
            sink.write({"kind": "event", "name": name, "span": span_id,
                        "ts": perf_counter() - self.t0,
                        "attrs": attrs or {}})

    # -- switches ----------------------------------------------------------
    def enable(self, sink=None) -> "Tracer":
        self.sink = sink
        self.t0 = perf_counter()
        self._id = 0
        self.enabled = True
        return self

    def disable(self) -> None:
        self.enabled = False
        sink, self.sink = self.sink, None
        if sink is not None:
            sink.flush()


#: process-wide default tracer — components fall back to this one, so
#: ``repro.obs.enable_tracing()`` turns on every span site at once
GLOBAL = Tracer()


def read_trace(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL trace file back into records (helper for tests and
    offline analysis)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
