"""repro: MemForest on JAX/TPU — write-efficient temporal agent memory framework."""
__version__ = "0.1.0"
