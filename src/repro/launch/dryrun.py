"""Multi-pod dry run: lower + compile EVERY (architecture x input shape) on
the production meshes, prove the sharding is coherent, and extract the
roofline inputs (memory analysis, cost analysis, collective bytes).

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out artifacts/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_8b --shape decode_32k

Per cell this produces artifacts/dryrun/<arch>__<shape>__<mesh>.json with:
compile status+time, per-device memory analysis, raw cost_analysis numbers,
collective bytes by kind (while-trip-count expanded), and the three roofline
terms. EXPERIMENTS.md §Dry-run / §Roofline read these artifacts.
"""
import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPE_ORDER, SHAPES, shape_applicable
from repro.launch import sharding as shd
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import activate_mesh, make_production_mesh
from repro.launch.roofline import roofline_terms
from repro.models import get_model
from repro.models.factory import input_specs
from repro.training.train_loop import make_train_step, train_state_specs


# ---------------------------------------------------------------------------
# cache shardings (name-based, like param rules)
# ---------------------------------------------------------------------------
def cache_shardings(cfg: ModelConfig, mesh, specs=None) -> Any:
    model = get_model(cfg)
    if specs is None:
        specs = model.cache_specs(2, 8)  # structure probe (tests only)
    names = tuple(mesh.axis_names)
    model_ok = lambda n: "model" if ("model" in names and n % dict(mesh.shape)["model"] == 0) else None

    def rule(path: str, leaf) -> P:
        name = path.split("/")[-1]
        nd = leaf.ndim
        DATA = tuple(a for a in ("pod", "data") if a in names)
        if name in ("k", "v", "kv_k", "kv_v", "self_k", "self_v", "cross_k", "cross_v"):
            # (L, B, S, Hkv, Dh): heads over model if divisible, else the
            # sequence dim (split-KV decode) so the cache never replicates
            h_ax = model_ok(cfg.num_kv_heads)
            s_ax = "model" if (h_ax is None and "model" in names) else None
            return P(None, DATA, s_ax, h_ax, None)
        if name == "wkv":      # (L, B, H, K, V)
            return P(None, DATA, model_ok(cfg.d_model // max(cfg.rwkv_head_size, 1)), None, None)
        if name == "ssd":      # (L, B, H, P, N)
            h = cfg.d_inner // max(cfg.ssm_head_dim, 1)
            return P(None, DATA, model_ok(h), None, None)
        if name == "conv":     # (L, B, W-1, C)
            return P(None, DATA, None, None)
        if name in ("shift_t", "shift_c"):  # (L, B, D)
            return P(None, DATA, None)
        if name == "lengths":  # (B,)
            return P(DATA)
        return P(*([None] * nd))

    def one(keypath, leaf):
        spec = rule(shd._path_str(keypath), leaf)
        spec = shd._drop_indivisible(spec, leaf, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, specs)


def _resize_cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    model = get_model(cfg)
    return model.cache_specs(batch, max_len)


# ---------------------------------------------------------------------------
def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, tcfg: TrainConfig,
               *, kv_replicate: bool = False):
    """Returns (fn, args_specs, in_shardings)."""
    model = get_model(cfg)
    kvh = cfg.num_kv_heads if kv_replicate else 0
    batch_sds = input_specs(cfg, shape)
    batch_sh = {k: shd.data_sharding(mesh, v.ndim, batch_size=v.shape[0])
                for k, v in batch_sds.items()}

    if shape.is_train:
        state_sds = train_state_specs(model, tcfg)
        state_sh: Dict[str, Any] = {
            "params": shd.param_shardings(mesh, state_sds["params"],
                                          moe_fsdp=cfg.moe_fsdp_params,
                                          kv_heads=kvh),
            "opt": {
                "m": shd.zero1_shardings(mesh, state_sds["opt"]["m"]),
                "v": shd.zero1_shardings(mesh, state_sds["opt"]["v"]),
                "step": NamedSharding(mesh, P()),
            },
        }
        if "err" in state_sds:
            state_sh["err"] = shd.zero1_shardings(mesh, state_sds["err"])
        step = make_train_step(model, tcfg)
        return step, (state_sds, batch_sds), (state_sh, batch_sh)

    param_sds = jax.eval_shape(model.init, jax.random.key(0))
    param_sh = shd.param_shardings(mesh, param_sds, moe_fsdp=cfg.moe_fsdp_params,
                                   kv_heads=kvh)

    if shape.kind == "prefill":
        fn = lambda params, batch: model.prefill(params, batch, shape.seq_len)
        return fn, (param_sds, batch_sds), (param_sh, batch_sh)

    # decode: one token against a seq_len cache
    cache_sds = _resize_cache_specs(cfg, shape.global_batch, shape.seq_len)
    cache_sh = cache_shardings(cfg, mesh, cache_sds)
    fn = model.decode
    return fn, (param_sds, batch_sds, cache_sds), (param_sh, batch_sh, cache_sh)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             tcfg: Optional[TrainConfig] = None,
             out_dir: Optional[str] = None,
             cfg_override: Optional[ModelConfig] = None,
             shape_override: Optional[ShapeConfig] = None,
             mesh_override=None, tag: str = "",
             kv_replicate: bool = False,
             donate: bool = False) -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    shape = shape_override or SHAPES[shape_name]
    tcfg = tcfg or TrainConfig(microbatch_size=0, grad_compression="none", zero1=True)
    mesh = mesh_override if mesh_override is not None else \
        make_production_mesh(multi_pod=(mesh_kind == "multi"))
    num_devices = mesh.size
    tp = dict(mesh.shape)["model"]

    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind + tag,
        "mesh_shape": list(mesh.shape.values()) if isinstance(mesh.shape, dict) else list(mesh.shape),
        "ok": False,
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        result["skipped"] = reason
        result["ok"] = True
        _dump(result, out_dir)
        return result

    try:
        fn, args, in_sh = build_cell(cfg, shape, mesh, tcfg,
                                     kv_replicate=kv_replicate)
        donate_args = ()
        if donate:
            # deployment aliasing: train state / decode cache update in place
            donate_args = (0,) if shape.is_train else (
                (2,) if shape.kind == "decode" else ())
        t0 = time.time()
        with activate_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate_args).lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else (ca or {})
        txt = compiled.as_text()
        colls = collective_bytes(txt)

        terms = roofline_terms(
            cfg, shape, num_devices=num_devices, tp=tp,
            collective_bytes_per_dev=colls.get("total", 0.0),
        )
        result.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_gb": ma.argument_size_in_bytes / 1e9,
                "output_gb": ma.output_size_in_bytes / 1e9,
                "temp_gb": ma.temp_size_in_bytes / 1e9,
                "peak_gb": (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 1e9,
            },
            cost_analysis_raw={
                "flops": ca.get("flops", -1.0),
                "bytes_accessed": ca.get("bytes accessed", -1.0),
            },
            collectives={k: v for k, v in colls.items()},
            roofline=terms,
        )
    except Exception as e:  # noqa: BLE001 — report compile failures as data
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    _dump(result, out_dir)
    return result


def _dump(result: Dict[str, Any], out_dir: Optional[str]) -> None:
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=1, default=float)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs on a tiny (2,4)/(2,2,2) mesh — CI")
    # --- perf-iteration knobs (EXPERIMENTS.md §Perf) -----------------------
    ap.add_argument("--tag", default="", help="suffix for artifact filenames")
    ap.add_argument("--mesh-shape", default=None,
                    help="override single-pod mesh, e.g. 64x4 (256 chips)")
    ap.add_argument("--serving-ep", action="store_true",
                    help="pure expert-parallel MoE weights (no FSDP)")
    ap.add_argument("--kv-replicate", action="store_true",
                    help="replicate wk/wv when kv_heads %% tp != 0")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--donate", action="store_true",
                    help="donate train state / decode cache buffers")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = SHAPE_ORDER if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    tcfg = TrainConfig(microbatch_size=args.microbatch)

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                if args.smoke:
                    from repro.configs import get_smoke_config
                    from repro.launch.mesh import make_mesh
                    cfg_o = get_smoke_config(arch)
                    shape_o = dataclasses.replace(
                        SHAPES[shape_name],
                        seq_len=64 if SHAPES[shape_name].kind != "decode" else 128,
                        global_batch=4,
                    )
                    mesh_o = make_mesh((2, 2, 2), ("pod", "data", "model")) \
                        if mesh_kind == "multi" else make_mesh((2, 4), ("data", "model"))
                    r = run_cell(arch, shape_name, mesh_kind, tcfg=tcfg,
                                 out_dir=args.out, cfg_override=cfg_o,
                                 shape_override=shape_o, mesh_override=mesh_o)
                else:
                    cfg_o = get_config(arch)
                    if args.serving_ep:
                        cfg_o = cfg_o.replace(moe_fsdp_params=False)
                    if args.no_remat:
                        cfg_o = cfg_o.replace(remat=False)
                    mesh_o = None
                    if args.mesh_shape and mesh_kind == "single":
                        from repro.launch.mesh import make_mesh
                        dims = tuple(int(x) for x in args.mesh_shape.split("x"))
                        mesh_o = make_mesh(dims, ("data", "model"))
                    r = run_cell(arch, shape_name, mesh_kind, tcfg=tcfg,
                                 out_dir=args.out, cfg_override=cfg_o,
                                 mesh_override=mesh_o, tag=args.tag,
                                 kv_replicate=args.kv_replicate,
                                 donate=args.donate)
                if r.get("skipped"):
                    status = "SKIP " + r["skipped"][:40]
                elif r["ok"]:
                    t = r["roofline"]
                    status = (
                        f"ok compile={r['compile_s']:.0f}s peak={r['memory']['peak_gb']:.1f}GB "
                        f"comp={t['compute_s']*1e3:.2f}ms mem={t['memory_s']*1e3:.2f}ms "
                        f"coll={t['collective_s']*1e3:.2f}ms dom={t['dominant']}"
                    )
                else:
                    status = "FAIL " + r.get("error", "?")[:80]
                    n_fail += 1
                print(f"[{arch:16s}|{shape_name:12s}|{mesh_kind:6s}] {status}", flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
