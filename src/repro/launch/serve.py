"""MemForest serving driver: the paper's serve-and-update lifecycle against
a live model backbone.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b \
        --sessions 8 --queries 20

Runs: (1) session ingestion through the parallel write path (batched chunk
extraction on the backbone encoder), (2) query serving (forest recall + tree
browse + answer), (3) reports the write/read latency split that paper
Tables 2-3 measure.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.config import MemForestConfig
from repro.core.encoder import HashingEncoder, ModelEncoder
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload
from repro.data.tokenizer import HashTokenizer
from repro.models import get_model
from repro.configs import get_smoke_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--queries", type=int, default=20)
    ap.add_argument("--encoder", default="model", choices=["model", "hashing"])
    ap.add_argument("--mode", default="llm+planner")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    wl = make_workload(num_entities=6, num_sessions=args.sessions,
                       transitions_per_entity=3, num_queries=args.queries,
                       seed=args.seed)

    if args.encoder == "model":
        cfg = get_smoke_config(args.arch).replace(d_model=128, num_heads=4,
                                                  num_kv_heads=4, head_dim=32)
        model = get_model(cfg)
        params = model.init(jax.random.key(0))
        encoder = ModelEncoder(cfg, params, HashTokenizer(cfg.vocab_size))
        mf_cfg = MemForestConfig(embed_dim=cfg.d_model, browse_mode=args.mode)
        print(f"backbone: {cfg.name} ({cfg.param_count():,} params)")
    else:
        mf_cfg = MemForestConfig(browse_mode=args.mode)
        encoder = HashingEncoder(dim=mf_cfg.embed_dim)

    mf = MemForestSystem(mf_cfg, encoder)

    t0 = time.perf_counter()
    for s in wl.sessions:
        st = mf.ingest_session(s)
        print(f"ingest {s.session_id}: {st.wall_s*1e3:6.1f}ms "
              f"facts+{st.facts_written} depth={st.llm_dependency_depth}")
    build_s = time.perf_counter() - t0
    print(f"\nwrite path: {build_s:.2f}s total, "
          f"{mf.write_stats.encoder_tokens:,} tokens, "
          f"{mf.write_stats.encoder_calls} model calls")
    print("memory scale:", mf.scale_stats())

    correct = 0
    ret_s = ans_s = 0.0
    for q in wl.queries:
        r = mf.query(q)
        ok = r.answer.strip().lower() == q.gold.strip().lower()
        correct += int(ok)
        ret_s += r.retrieval_s
        ans_s += r.answer_s
        mark = "+" if ok else "-"
        print(f" [{mark}] {q.text}  ->  {r.answer!r} (gold {q.gold!r})")
    n = len(wl.queries)
    print(f"\naccuracy {correct}/{n} = {correct/n:.1%}  "
          f"retrieval {ret_s/n*1e3:.1f}ms/q  answer {ans_s/n*1e3:.1f}ms/q")


if __name__ == "__main__":
    main()
