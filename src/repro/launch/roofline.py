"""Three-term roofline analysis (TPU v5e target).

    compute term    = FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HBM bytes / (chips x 819 GB/s)
    collective term = collective bytes / (chips x 50 GB/s/link)

Sources: collective bytes come from the compiled HLO (hlo_analysis, with
while-loop trip-count expansion). FLOPs and HBM bytes use the ANALYTIC model
below, because ``cost_analysis()`` counts scan bodies exactly once (probe in
EXPERIMENTS.md §Dry-run) — the raw cost_analysis numbers are still recorded
next to the corrected ones in every table row.

MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per the assignment; the
ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute, attention, and MoE
capacity waste.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.config import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


# ---------------------------------------------------------------------------
# analytic FLOPs (per-token forward, whole model)
# ---------------------------------------------------------------------------
def _attn_flops_per_tok(cfg: ModelConfig, ctx: float) -> float:
    """qk^T + pv for one token attending to `ctx` keys."""
    return 2 * cfg.num_heads * cfg.head_dim * ctx * 2


def _dense_layer_flops(cfg: ModelConfig, ctx: float) -> float:
    proj = 2 * cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim) + 2 * cfg.q_dim * cfg.d_model
    mlp_mats = 3 if cfg.mlp_activation == "swiglu" else 2
    mlp = mlp_mats * 2 * cfg.d_model * cfg.d_ff
    return proj + _attn_flops_per_tok(cfg, ctx) + mlp


def _moe_layer_flops(cfg: ModelConfig, ctx: float) -> float:
    proj = 2 * cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim) + 2 * cfg.q_dim * cfg.d_model
    router = 2 * cfg.d_model * cfg.num_experts
    experts = 3 * 2 * cfg.d_model * cfg.d_ff * cfg.experts_per_token * cfg.moe_capacity_factor
    return proj + _attn_flops_per_tok(cfg, ctx) + router + experts


def _rwkv_layer_flops(cfg: ModelConfig) -> float:
    D = cfg.d_model
    proj = 5 * 2 * D * D + 2 * D * 64 + 2 * 64 * D
    wkv = 5 * D * cfg.rwkv_head_size
    cmix = 2 * 2 * D * cfg.d_ff
    return proj + wkv + cmix


def _mamba_layer_flops(cfg: ModelConfig) -> float:
    D, Din, N = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim
    H = Din // cfg.ssm_head_dim
    proj = 2 * D * (2 * Din + 2 * N + H) + 2 * Din * D
    conv = 2 * cfg.ssm_conv_width * (Din + 2 * N)
    ssd = 5 * Din * N
    return proj + conv + ssd


def fwd_flops_per_token(cfg: ModelConfig, ctx: float) -> float:
    """Whole-model forward FLOPs for ONE decoder token with context `ctx`."""
    unembed = 2 * cfg.d_model * cfg.vocab_size
    if cfg.family in ("dense", "vlm"):
        return cfg.num_layers * _dense_layer_flops(cfg, ctx) + unembed
    if cfg.family == "moe":
        return cfg.num_layers * _moe_layer_flops(cfg, ctx) + unembed
    if cfg.family == "ssm":
        return cfg.num_layers * _rwkv_layer_flops(cfg) + unembed
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // max(cfg.attn_every, 1)
        shared = _dense_layer_flops(cfg, ctx)
        return cfg.num_layers * _mamba_layer_flops(cfg) + n_attn * shared + unembed
    if cfg.family == "encdec":
        # decoder token: self-attn + cross-attn + mlp
        proj = 2 * cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim) + 2 * cfg.q_dim * cfg.d_model
        mlp = 2 * 2 * cfg.d_model * cfg.d_ff
        cross = 2 * cfg.d_model * cfg.q_dim + 2 * cfg.q_dim * cfg.d_model \
            + _attn_flops_per_tok(cfg, cfg.encoder_seq_len)
        per_tok = cfg.num_layers * (proj + _attn_flops_per_tok(cfg, ctx) + cross + mlp)
        return per_tok + unembed
    raise ValueError(cfg.family)


def encoder_flops(cfg: ModelConfig) -> float:
    """Whisper encoder (runs once per prefill/train step, per sequence)."""
    if cfg.family != "encdec":
        return 0.0
    Senc = cfg.encoder_seq_len
    proj = 2 * cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim) + 2 * cfg.q_dim * cfg.d_model
    mlp = 2 * 2 * cfg.d_model * cfg.d_ff
    per_tok = proj + _attn_flops_per_tok(cfg, Senc) + mlp
    cross_kv = 2 * cfg.d_model * cfg.kv_dim * 2 * cfg.num_layers  # per enc token
    return Senc * (cfg.encoder_layers * per_tok + cross_kv)


@dataclass
class FlopsReport:
    fwd_total: float          # whole step, all devices
    hlo_equiv: float          # incl. train backward (+ remat recompute)
    model_flops: float        # 6·N(active)·D  (spec definition)


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig) -> FlopsReport:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        ctx = float(S)
        tokens = B  # one new token per sequence
        fwd = tokens * fwd_flops_per_token(cfg, ctx)
    else:
        ctx = (S + 1) / 2  # causal average
        tokens = B * S
        fwd = tokens * fwd_flops_per_token(cfg, ctx) + B * encoder_flops(cfg)
    if shape.is_train:
        mult = 4.0 if cfg.remat else 3.0   # fwd + 2x bwd (+ remat refwd)
    else:
        mult = 1.0
    n_active = cfg.param_count(active_only=True)
    if shape.is_train:
        model = 6.0 * n_active * tokens
    else:
        model = 2.0 * n_active * tokens
    return FlopsReport(fwd_total=fwd, hlo_equiv=fwd * mult, model_flops=model)


# ---------------------------------------------------------------------------
# analytic HBM bytes (per device per step, leading terms)
# ---------------------------------------------------------------------------
@dataclass
class BytesReport:
    weights: float
    optimizer: float
    activations: float
    cache: float
    total: float


def analytic_bytes(cfg: ModelConfig, shape: ShapeConfig, *, num_devices: int,
                   tp: int, microbatches: int = 1) -> BytesReport:
    B, S = shape.global_batch, shape.seq_len
    p_bytes = cfg.param_count() * 2  # bf16
    p_local = p_bytes / tp
    dp = num_devices // tp

    if shape.is_train:
        weights = p_local * 3.0 * microbatches   # fwd + bwd(dW, dX) re-reads
        if cfg.remat:
            weights += p_local * microbatches
        # AdamW: m,v fp32 read+write (ZeRO-1: sharded over all devices),
        # fp32 grads read+write on the TP shard
        opt = (cfg.param_count() * 4 * 4) / num_devices + (cfg.param_count() * 4 * 2) / tp
    else:
        weights = p_local
        opt = 0.0

    tokens_local = (B / dp) * (1 if shape.kind == "decode" else S)
    act_tensors = 10.0  # materialized per layer (resid, norms, proj, mlp, ...)
    act = tokens_local * cfg.d_model * 2 * act_tensors * cfg.num_layers
    if shape.is_train:
        act *= 2.0  # backward re-touches activations

    cache = 0.0
    if shape.kind == "decode":
        b_local = B / dp
        if cfg.family in ("dense", "moe", "vlm"):
            cache = cfg.num_layers * b_local * S * cfg.kv_dim * 2 * 2  # k+v, bf16
            cache /= tp  # heads-sharded if divisible, else sequence-sharded
        elif cfg.family == "ssm":
            H = cfg.d_model // cfg.rwkv_head_size
            cache = cfg.num_layers * b_local * H * cfg.rwkv_head_size ** 2 * 4 * 2 / tp
        elif cfg.family == "hybrid":
            Din, N = cfg.d_inner, cfg.ssm_state_dim
            H = Din // cfg.ssm_head_dim
            ssd = cfg.num_layers * b_local * H * cfg.ssm_head_dim * N * 4 * 2 / tp
            G = cfg.num_layers // max(cfg.attn_every, 1)
            kv = G * b_local * S * cfg.kv_dim * 2 * 2 / tp
            cache = ssd + kv
        elif cfg.family == "encdec":
            cache = cfg.num_layers * b_local * (S + cfg.encoder_seq_len) * cfg.kv_dim * 2 * 2 / tp
    elif shape.kind == "prefill":
        b_local = B / dp
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            cache = cfg.num_layers * b_local * S * cfg.kv_dim * 2 * 2 / tp  # write k+v

    total = weights + opt + act + cache
    return BytesReport(weights, opt, act, cache, total)


# ---------------------------------------------------------------------------
# the three terms
# ---------------------------------------------------------------------------
def roofline_terms(cfg: ModelConfig, shape: ShapeConfig, *, num_devices: int,
                   tp: int, collective_bytes_per_dev: float,
                   microbatches: int = 1) -> Dict[str, float]:
    fl = analytic_flops(cfg, shape)
    by = analytic_bytes(cfg, shape, num_devices=num_devices, tp=tp,
                        microbatches=microbatches)
    compute_s = fl.hlo_equiv / (num_devices * PEAK_FLOPS)
    memory_s = by.total / HBM_BW
    collective_s = collective_bytes_per_dev / LINK_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    useful_ratio = fl.model_flops / max(fl.hlo_equiv, 1.0)
    step_s = max(compute_s, memory_s, collective_s)
    mfu = (fl.model_flops / num_devices / max(step_s, 1e-12)) / PEAK_FLOPS
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "hlo_flops_total": fl.hlo_equiv,
        "model_flops": fl.model_flops,
        "useful_ratio": useful_ratio,
        "roofline_mfu": mfu,
        "bytes_weights": by.weights,
        "bytes_opt": by.optimizer,
        "bytes_act": by.activations,
        "bytes_cache": by.cache,
        "bytes_total": by.total,
    }
