"""Logical sharding rules (MaxText-style) for the production mesh.

Mesh axes: ("pod", "data", "model") multi-pod, ("data", "model") single-pod.
`pod`+`data` are the data-parallel axes; `model` is tensor/expert-parallel.

Models call :func:`constrain` on activations with *logical* specs; axes not
present in the ambient mesh are silently dropped, so the same model code runs
on any mesh (including none — smoke tests on one CPU device).

Parameter shardings are name-based: :func:`param_pspec` maps a param path to
a PartitionSpec, and :func:`param_shardings` builds the full pytree used as
``in_shardings`` at jit time.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

DATA_AXES: Tuple[str, ...] = ("pod", "data")
MODEL_AXIS = "model"


def get_abstract_mesh():
    """Compat shim: ``jax.sharding.get_abstract_mesh`` only exists in newer
    JAX. On older versions fall back to the thread-local physical mesh (set
    by the ``with Mesh(...)`` context manager), which exposes the same
    ``.empty`` / ``.axis_names`` / ``.shape`` surface the callers need."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh_lib

    return _mesh_lib.thread_resources.env.physical_mesh


def current_mesh_axes() -> Tuple[str, ...]:
    am = get_abstract_mesh()
    return () if am.empty else tuple(am.axis_names)


def _clean_spec(spec, names) -> P:
    out = []
    for s in spec:
        if s is None:
            out.append(None)
        elif isinstance(s, str):
            out.append(s if s in names else None)
        else:
            t = tuple(a for a in s if a in names)
            out.append(t if t else None)
    return P(*out)


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that tolerates absent mesh axes / no mesh."""
    names = current_mesh_axes()
    if not names:
        return x
    return jax.lax.with_sharding_constraint(x, _clean_spec(spec, names))


def batch_spec(*rest) -> Tuple:
    """Leading batch dim sharded over all data axes."""
    return (DATA_AXES,) + rest


# ---------------------------------------------------------------------------
# parameter sharding rules (name-based; first match wins)
# ---------------------------------------------------------------------------
# Conventions (see models/*):
#   wq/wk/wv: (D, H*Dh)  -> shard output (head) dim over model
#   wo:       (H*Dh, D)  -> shard input (head) dim over model
#   w_gate/w_up/wi: (D, F) -> shard F over model
#   w_down/wd:      (F, D) -> shard F over model
#   MoE expert weights: (E, D, F)/(E, F, D) -> shard E over model
#   router: (D, E) -> replicated (small)
#   embed: (V, D) -> shard V over model; unembed (D, V) -> shard V
#   norms / biases / scalars -> replicated
#   rwkv/mamba projections: (D, X) -> X over model; conv/ssm per-channel
#   params with leading scan-layer dim L get None prepended via _trail


def _trail(nd: int, *spec) -> P:
    """PartitionSpec with `spec` on the trailing len(spec) dims."""
    pad = (None,) * (nd - len(spec))
    return P(*(pad + spec))


def param_pspec(path: str, leaf: Any, *, moe_fsdp: bool = True) -> P:
    nd = leaf.ndim if hasattr(leaf, "ndim") else 0
    name = path.split("/")[-1]
    if nd <= 1:
        return P()
    # embeddings: shard vocab dim over model
    if name == "embed":
        return _trail(nd, MODEL_AXIS, None)
    if name == "unembed":
        return _trail(nd, None, MODEL_AXIS)
    # attention projections
    if name in ("wq", "wk", "wv", "w_kv_cross_k", "w_kv_cross_v"):
        return _trail(nd, None, MODEL_AXIS)
    if name == "wo":
        return _trail(nd, MODEL_AXIS, None)
    # MoE experts: (E, D, F) / (E, F, D) — expert dim over model, second dim
    # FSDP-sharded over the data axes (a 235B-A22B's expert weights are the
    # bulk of its 470GB; without FSDP they exceed per-chip HBM). Serving
    # uses pure EP (moe_fsdp=False) to avoid per-step weight gathers.
    if name in ("we_gate", "we_up", "we_down"):
        return _trail(nd, MODEL_AXIS, DATA_AXES if moe_fsdp else None, None)
    if name == "router":
        return P()
    # MLP
    if name in ("w_gate", "w_up", "wi"):
        return _trail(nd, None, MODEL_AXIS)
    if name in ("w_down", "wd"):
        return _trail(nd, MODEL_AXIS, None)
    # rwkv time-mix / channel-mix projections (D, D) or (D, F)
    if name in ("wr", "wk_t", "wv_t", "wg", "w_cm_k"):
        return _trail(nd, None, MODEL_AXIS)
    if name in ("wo_t", "w_cm_v"):
        return _trail(nd, MODEL_AXIS, None)
    # mamba
    if name == "w_in":
        return _trail(nd, None, MODEL_AXIS)
    if name == "w_out":
        return _trail(nd, MODEL_AXIS, None)
    # default: replicate
    return P()


def _path_str(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _drop_indivisible(spec: P, leaf, mesh: Mesh) -> P:
    """Remove mesh axes from dims they don't divide evenly (e.g. a 51865
    vocab can't shard 16 ways — replicate that dim instead of failing)."""
    if not hasattr(leaf, "shape"):
        return spec
    sizes = dict(mesh.shape)
    out = []
    for i, s in enumerate(tuple(spec)):
        if s is None:
            out.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        width = 1
        for a in axes:
            width *= sizes.get(a, 1)
        out.append(s if leaf.shape[i] % width == 0 else None)
    return P(*out)


def param_shardings(mesh: Mesh, params_tree: Any, *, moe_fsdp: bool = True,
                    kv_heads: int = 0) -> Any:
    """Pytree of NamedShardings matching `params_tree` (arrays or SDS).

    kv_heads: when > 0 and not divisible by the TP width, the wk/wv
    projections are REPLICATED (a few MB/layer) instead of column-sharded —
    otherwise every layer's k/v activations get all-gathered across the
    model axis (GQA kv narrower than TP; see EXPERIMENTS.md §Perf)."""
    tp = dict(mesh.shape).get(MODEL_AXIS, 1)
    kv_replicate = kv_heads > 0 and kv_heads % tp != 0

    def one(keypath, leaf):
        path = _path_str(keypath)
        name = path.split("/")[-1]
        if kv_replicate and name in ("wk", "wv"):
            return NamedSharding(mesh, P())
        spec = param_pspec(path, leaf, moe_fsdp=moe_fsdp)
        # drop axes absent from this mesh, then indivisible placements
        spec = _clean_spec(tuple(spec), tuple(mesh.axis_names))
        spec = _drop_indivisible(spec, leaf, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def data_sharding(mesh: Mesh, ndim: int, batch_size: Optional[int] = None) -> NamedSharding:
    axes = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    if batch_size is not None and axes:
        width = 1
        for a in axes:
            width *= dict(mesh.shape)[a]
        if batch_size % width != 0:
            # batch too small/ragged for full DP: replicate (e.g. the
            # long_500k single-sequence decode cell)
            axes = ()
    return NamedSharding(mesh, P(axes if axes else None, *([None] * (ndim - 1))))


def zero1_pspec(path: str, leaf: Any, dp_size: int = 0) -> P:
    """Optimizer-moment sharding (ZeRO-1): the param spec plus the data axes
    on the LARGEST free dim that divides evenly by the DP width. Falls back
    to the plain param spec if no dim qualifies (e.g. layer-stacked scalars).
    """
    base = tuple(param_pspec(path, leaf))
    nd = leaf.ndim if hasattr(leaf, "ndim") else 0
    base = base + (None,) * (nd - len(base))
    out = list(base)
    # FSDP-sharded params already consume the data axes
    if any(s == DATA_AXES for s in out):
        return P(*out)
    if hasattr(leaf, "shape") and dp_size > 0:
        best, best_size = -1, 0
        for i, s in enumerate(out):
            if s is None and leaf.shape[i] % dp_size == 0 and leaf.shape[i] > best_size:
                best, best_size = i, leaf.shape[i]
        if best >= 0:
            out[best] = DATA_AXES
    return P(*out)


def zero1_shardings(mesh: Mesh, params_tree: Any) -> Any:
    sizes = dict(mesh.shape)
    dp = 1
    for a in DATA_AXES:
        dp *= sizes.get(a, 1)

    def one(keypath, leaf):
        spec = zero1_pspec(_path_str(keypath), leaf, dp_size=dp)
        spec = _clean_spec(tuple(spec), tuple(mesh.axis_names))
        spec = _drop_indivisible(spec, leaf, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)
