"""End-to-end training driver with checkpoint/restart + fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
        --steps 200 --batch 8 --seq 128

On this CPU container `--smoke` selects the reduced config (the full configs
are dry-run only). On real hardware the same driver runs the full config on
the production mesh: the mesh/sharding/step code paths are identical — only
the config and device set change.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.runtime import checkpoint as ckpt
from repro.runtime.fault_tolerance import StragglerMitigator
from repro.training.train_loop import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    tcfg = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 5),
        microbatch_size=args.microbatch,
        grad_compression=args.grad_compression,
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt_dir,
    )
    print(f"arch={cfg.name} params≈{cfg.param_count():,} devices={len(jax.devices())}")

    state = init_train_state(model, tcfg, jax.random.key(tcfg.seed))
    start_step = 0
    if args.resume:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state, extra = ckpt.restore(args.ckpt_dir, state)
            start_step = extra.get("step", latest)
            print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=tcfg.seed)
    mitigator = StragglerMitigator()

    t_start = time.perf_counter()
    tokens_done = 0
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dur = time.perf_counter() - t0
        mitigator.check(step, "local", dur)
        tokens_done += args.batch * args.seq
        if step % args.log_every == 0 or step == args.steps - 1:
            tps = tokens_done / (time.perf_counter() - t_start)
            print(f"step {step:5d} loss {loss:7.4f} lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):6.2f} {dur*1e3:6.1f}ms "
                  f"{tps:,.0f} tok/s", flush=True)
        if (step + 1) % tcfg.checkpoint_every == 0:
            path = ckpt.save(args.ckpt_dir, step + 1, state,
                             extra={"step": step + 1, "arch": cfg.name})
            print(f"  checkpoint -> {path}")
    if mitigator.events:
        print(f"straggler events: {len(mitigator.events)}")
    print("done.")


if __name__ == "__main__":
    main()
