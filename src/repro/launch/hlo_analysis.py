"""Collective-traffic analysis of compiled (SPMD-partitioned) HLO.

``compiled.cost_analysis()`` does not report collective bytes, and — as the
scan probe in EXPERIMENTS.md §Dry-run documents — XLA counts while-loop
bodies exactly ONCE. This parser therefore:

  1. splits the compiled HLO text into computations,
  2. sums per-computation collective payload bytes (result-shape convention;
     reduce-scatter is scaled by its group size so the bytes reflect the
     pre-scatter operand),
  3. recovers every while loop's trip count from its condition computation
     (the s32 bound constant), and
  4. expands collective bytes recursively: eff(comp) = own + Σ trip × eff(body),

so a per-layer all-reduce inside a scan over 94 layers is counted 94 times —
what actually crosses the links per step.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[dims] shape literal in `text`."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Returns {op_kind: effective bytes per device per step} plus "total"
    and "num_collectives"."""
    comps = parse_computations(hlo_text)

    own: Dict[str, Dict[str, float]] = {c: defaultdict(float) for c in comps}
    whiles: Dict[str, List[Tuple[str, str]]] = {c: [] for c in comps}
    counts: Dict[str, int] = defaultdict(int)

    for cname, lines in comps.items():
        for line in lines:
            s = line.strip()
            if not s.startswith("%") and not s.startswith("ROOT"):
                continue
            for op in _COLLECTIVES:
                # match `= <shape> op-name(` (with optional -start/-done forms)
                if re.search(rf"\s{op}(-start)?\(", s):
                    lhs = s.split(f"{op}(")[0].split(f"{op}-start(")[0]
                    nbytes = _shape_bytes(lhs.split("=", 1)[-1])
                    if op == "reduce-scatter":
                        g = _GROUPS_RE.search(s)
                        if g:
                            nbytes *= int(g.group(2))
                    own[cname][op] += nbytes
                    counts[op] += 1
                    break
            wm = _WHILE_RE.search(s)
            if wm:
                whiles[cname].append((wm.group(1), wm.group(2)))

    def trip_count(cond_comp: str) -> int:
        best = 1
        for line in comps.get(cond_comp, []):
            for m in _CONST_RE.finditer(line):
                best = max(best, int(m.group(1)))
        return best

    memo: Dict[str, Dict[str, float]] = {}

    def eff(cname: str, stack=()) -> Dict[str, float]:
        if cname in memo:
            return memo[cname]
        if cname in stack:
            return defaultdict(float)
        out: Dict[str, float] = defaultdict(float)
        for k, v in own.get(cname, {}).items():
            out[k] += v
        for cond, body in whiles.get(cname, []):
            trips = trip_count(cond)
            sub = eff(body, stack + (cname,))
            for k, v in sub.items():
                out[k] += trips * v
        memo[cname] = out
        return out

    entry = None
    for line in hlo_text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(2)
                break
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""

    result = dict(eff(entry))
    result["total"] = float(sum(v for k, v in result.items()))
    result["num_collectives"] = float(sum(counts.values()))
    return result


def while_trip_counts(hlo_text: str) -> List[int]:
    """Debug helper: all loop bounds found."""
    comps = parse_computations(hlo_text)
    out = []
    for lines in comps.values():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond = m.group(1)
                best = 1
                for l2 in comps.get(cond, []):
                    for c in _CONST_RE.finditer(l2):
                        best = max(best, int(c.group(1)))
                out.append(best)
    return out
