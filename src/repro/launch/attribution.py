"""Per-op collective attribution for compiled HLO — the §Perf profiler.

    PYTHONPATH=src python -m repro.launch.attribution --arch llama3_8b \
        --shape train_4k [--mesh-shape 64x4] [--microbatch 64] [--kv-replicate]

Prints the top collective ops by EFFECTIVE bytes (while-loop trip counts
expanded, nested loops multiplied), with shapes and jax op_name metadata —
how the B5/C3 §Perf fixes were found.
"""
import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import re  # noqa: E402
from typing import Dict, List, Tuple  # noqa: E402

import jax  # noqa: E402

from repro.config import TrainConfig  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.hlo_analysis import (  # noqa: E402
    _CONST_RE, _SHAPE_RE, _WHILE_RE, _shape_bytes, parse_computations,
)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def attribute(hlo_text: str, top: int = 15) -> List[Tuple[float, str, int, str, List[str]]]:
    comps = parse_computations(hlo_text)

    parents: Dict[str, Tuple[str, str]] = {}  # body -> (parent, cond)
    for parent, lines in comps.items():
        for line in lines:
            w = _WHILE_RE.search(line)
            if w:
                parents[w.group(2)] = (parent, w.group(1))

    def trip(cond: str) -> int:
        best = 1
        for l2 in comps.get(cond, []):
            for c in _CONST_RE.finditer(l2):
                best = max(best, int(c.group(1)))
        return best

    def eff_mult(cname: str, seen=()) -> int:
        if cname not in parents or cname in seen:
            return 1
        parent, cond = parents[cname]
        return trip(cond) * eff_mult(parent, seen + (cname,))

    rows = []
    for cname, lines in comps.items():
        mult = eff_mult(cname)
        for line in lines:
            s = line.strip()
            for op in _COLLECTIVES:
                if re.search(rf"\s{op}(-start)?\(", s):
                    lhs = s.split(f"{op}(")[0].split(f"{op}-start(")[0]
                    b = _shape_bytes(lhs.split("=", 1)[-1])
                    mm = re.search(r'op_name="([^"]+)"', s)
                    name = mm.group(1)[-80:] if mm else "?"
                    shapes = [m.group(0) for m in _SHAPE_RE.finditer(
                        lhs.split("=", 1)[-1])][:4]
                    rows.append((b * mult, op, mult, name, shapes))
                    break
    rows.sort(reverse=True)
    return rows[:top]


def main() -> None:
    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import activate_mesh, make_mesh, make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh-shape", default=None)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--kv-replicate", action="store_true")
    ap.add_argument("--serving-ep", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.serving_ep:
        cfg = cfg.replace(moe_fsdp_params=False)
    if args.mesh_shape:
        dims = tuple(int(x) for x in args.mesh_shape.split("x"))
        mesh = make_mesh(dims, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    tcfg = TrainConfig(microbatch_size=args.microbatch)

    fn, cell_args, in_sh = build_cell(cfg, SHAPES[args.shape], mesh, tcfg,
                                      kv_replicate=args.kv_replicate)
    with activate_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*cell_args).compile()
    for b, op, mult, name, shapes in attribute(compiled.as_text(), args.top):
        print(f"{b/1e9:8.1f}GB  {op:18s} x{mult:<5d} {shapes}  {name}")


if __name__ == "__main__":
    main()
