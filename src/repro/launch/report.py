"""Aggregate dry-run artifacts into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report artifacts/dryrun [--md]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

ARCH_ORDER = [
    "whisper_base", "rwkv6_1b6", "zamba2_7b", "qwen3_moe_235b", "olmoe_1b_7b",
    "starcoder2_7b", "phi3_mini", "llama3_8b", "granite_3_8b", "pixtral_12b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str) -> List[Dict]:
    rows = []
    for f in sorted(os.listdir(out_dir)):
        if f.endswith(".json"):
            with open(os.path.join(out_dir, f)) as fh:
                rows.append(json.load(fh))
    return rows


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.2f}"


BF16_WIRE_CORRECTION = 0.5  # XLA:CPU legalizes bf16->f32; TPU wire bytes for
                            # bf16 traffic are half the measured (see
                            # EXPERIMENTS.md §Dry-run "measurement notes")


def corrected_terms(r: Dict) -> Dict:
    """Recompute the three terms with the TPU bf16 wire correction."""
    t = dict(r["roofline"])
    ndev = 1
    for d in r["mesh_shape"]:
        ndev *= d
    coll = t["collective_s"] * BF16_WIRE_CORRECTION
    step = max(t["compute_s"], t["memory_s"], coll)
    t["collective_s_tpu"] = coll
    t["dominant_tpu"] = max(
        ("compute", t["compute_s"]), ("memory", t["memory_s"]),
        ("collective", coll), key=lambda kv: kv[1])[0]
    t["mfu_tpu"] = (t["model_flops"] / ndev / max(step, 1e-12)) / 197e12
    return t


def table(rows: List[Dict], mesh: str, md: bool = True) -> str:
    out = []
    hdr = ("| arch | shape | compile_s | peak GB/dev | compute ms | memory ms | "
           "collective ms (tpu-est) | dominant | 6ND/HLO | roofline-MFU |")
    sep = "|" + "---|" * 10
    out.append(hdr)
    out.append(sep)
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = next((x for x in rows if x["arch"] == arch and
                      x["shape"] == shape and x["mesh"] == mesh), None)
            if r is None:
                continue
            if r.get("skipped"):
                out.append(f"| {arch} | {shape} | — | — | — | — | — | "
                           f"SKIP (full-attn) | — | — |")
                continue
            if not r.get("ok"):
                out.append(f"| {arch} | {shape} | FAIL | | | | | | | |")
                continue
            t = corrected_terms(r)
            out.append(
                f"| {arch} | {shape} | {r['compile_s']:.0f} | "
                f"{r['memory']['peak_gb']:.1f} | {fmt_ms(t['compute_s'])} | "
                f"{fmt_ms(t['memory_s'])} | {fmt_ms(t['collective_s_tpu'])} | "
                f"{t['dominant_tpu']} | {t['useful_ratio']:.2f} | "
                f"{t['mfu_tpu']*100:.1f}% |"
            )
    return "\n".join(out)


def summary(rows: List[Dict]) -> str:
    ok = sum(1 for r in rows if r.get("ok") and not r.get("skipped"))
    skip = sum(1 for r in rows if r.get("skipped"))
    fail = sum(1 for r in rows if not r.get("ok"))
    return f"cells: {ok} compiled, {skip} skipped (documented), {fail} failed"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("out_dir")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = load(args.out_dir)
    print(summary(rows))
    print()
    print(table(rows, args.mesh))


if __name__ == "__main__":
    main()
