"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic re-mesh ladder, tests)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1) -> Optional[Mesh]:
    """Largest mesh expressible on the actually-available devices."""
    n = len(jax.devices())
    if n == 1:
        return None
    data = n // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))
