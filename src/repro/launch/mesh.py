"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

# jax.sharding.AxisType landed after the pinned JAX version; older
# jax.make_mesh has no axis_types kwarg, and its default (auto) matches what
# we want — so only pass the kwarg when the running JAX understands it.
try:
    from jax.sharding import AxisType as _AxisType
except ImportError:
    _AxisType = None


def _make(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    if _AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic re-mesh ladder, tests)."""
    return _make(shape, axes)


def activate_mesh(mesh: Mesh):
    """Compat for ``jax.set_mesh`` (newer JAX): on older versions the Mesh
    object itself is the context manager that installs the thread-local mesh."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def make_data_mesh(devices: int = 0, axis: str = "data") -> Optional[Mesh]:
    """1-D serve mesh over the first ``devices`` local devices (0 = all).
    Returns None when fewer than 2 devices are available/requested — callers
    treat None as the single-device fast path (Forest.set_mesh(None)).

    Host-simulated multi-device testing: set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE the first
    jax import, then ``make_data_mesh(N)``."""
    import numpy as np

    avail = jax.devices()
    n = len(avail) if devices <= 0 else min(devices, len(avail))
    if n <= 1:
        return None
    return Mesh(np.asarray(avail[:n]), (axis,))


def make_host_mesh(model_parallel: int = 1) -> Optional[Mesh]:
    """Largest mesh expressible on the actually-available devices."""
    n = len(jax.devices())
    if n == 1:
        return None
    data = n // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))
