"""Write path stage 2: canonical fact consolidation (paper §4.1).

Parallel chunk extraction fragments evidence (overlapping chunks re-state the
same fact); canonicalization repairs that WITHOUT reading accumulated memory
state: candidates are normalized, exact-key duplicates merged, and near-
duplicates collapsed by embedding similarity within the batch and against
the existing fact store (same subject+attribute only, via topk_sim).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.forest import Forest
from repro.core.types import CanonicalFact, RawCandidate


def _norm(s: str) -> str:
    return " ".join(s.strip().lower().split())


def canonicalize(
    candidates: List[RawCandidate],
    embs: Optional[np.ndarray],
    forest: Forest,
    sim_threshold: float = 0.92,
) -> List[CanonicalFact]:
    """Returns the NEW canonical facts (already registered in the forest's
    fact store). Duplicates merge their source references instead.

    One-group form of :func:`canonicalize_batch` — a single definition of
    the dedup rules keeps the batched/sequential state-equivalence contract
    unbreakable by one-sided edits."""
    return canonicalize_batch([(candidates, embs)], forest, sim_threshold)[0]


def canonicalize_batch(
    groups: List[Tuple[List[RawCandidate], Optional[np.ndarray]]],
    forest: Forest,
    sim_threshold: float = 0.92,
) -> List[List[CanonicalFact]]:
    """Multi-session canonicalization in a SINGLE pass (one group per
    session, in arrival order). Semantics match calling :func:`canonicalize`
    once per group in order — same facts, same ids, same merged sources —
    but the two hot costs are batch-amortized:

      * the existing-key map over the fact store is built ONCE per batch
        instead of once per session (the per-session rebuild is O(|facts|),
        which made a sequential ingest loop quadratic in stored facts);
      * the near-duplicate similarity scan inside each group is one gemm
        over the group's fact-index rows (``embs @ embs.T``) instead of a
        python pair loop — the vectorized similarity gate.

    Returns the per-group lists of NEW canonical facts (registered in the
    forest's fact store, in group order)."""
    existing = {}
    for f in forest.facts:
        if forest.fact_alive[f.fact_id]:
            existing[(_norm(f.subject), _norm(f.attribute), _norm(f.value),
                      round(f.ts, 1))] = f

    out: List[List[CanonicalFact]] = []
    for candidates, embs in groups:
        new_facts: List[CanonicalFact] = []
        new_idx: List[int] = []            # candidate index of each new fact
        batch_seen = {}
        sims = embs @ embs.T if embs is not None and len(candidates) else None

        for i, c in enumerate(candidates):
            key = (_norm(c.subject), _norm(c.attribute), _norm(c.value), round(c.ts, 1))
            if key in batch_seen:
                batch_seen[key].sources.append(c.source)
                continue
            if key in existing:
                existing[key].sources.append(c.source)
                continue
            dup = None
            if sims is not None:
                for nf, j in zip(new_facts, new_idx):
                    if (_norm(nf.subject), _norm(nf.attribute)) == key[:2] and \
                            float(sims[i, j]) >= sim_threshold and \
                            _norm(nf.value) == key[2]:
                        dup = nf
                        break
            if dup is not None:
                dup.sources.append(c.source)
                continue
            fact = CanonicalFact(
                fact_id=-1,
                text=c.text,
                subject=c.subject.strip(),
                attribute=c.attribute.strip(),
                value=c.value.strip(),
                ts=c.ts,
                prev_value=c.prev_value,
                sources=[c.source],
                emb=embs[i] if embs is not None else None,
            )
            batch_seen[key] = fact
            new_facts.append(fact)
            new_idx.append(i)

        for f in new_facts:
            forest.add_fact(f)
            # later groups must see this group's facts as existing state,
            # exactly as sequential per-session canonicalize calls would
            existing[(_norm(f.subject), _norm(f.attribute), _norm(f.value),
                      round(f.ts, 1))] = f
        out.append(new_facts)
    return out
