"""Write path stage 2: canonical fact consolidation (paper §4.1).

Parallel chunk extraction fragments evidence (overlapping chunks re-state the
same fact); canonicalization repairs that WITHOUT reading accumulated memory
state: candidates are normalized, exact-key duplicates merged, and near-
duplicates collapsed by embedding similarity within the batch and against
the existing fact store (same subject+attribute only, via topk_sim).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.forest import Forest
from repro.core.types import CanonicalFact, RawCandidate


def _norm(s: str) -> str:
    return " ".join(s.strip().lower().split())


def canonicalize(
    candidates: List[RawCandidate],
    embs: Optional[np.ndarray],
    forest: Forest,
    sim_threshold: float = 0.92,
) -> List[CanonicalFact]:
    """Returns the NEW canonical facts (already registered in the forest's
    fact store). Duplicates merge their source references instead."""
    new_facts: List[CanonicalFact] = []
    batch_seen = {}

    # existing-key lookup (persistent state read, host-side hash — not an
    # LLM call; this is exactly what makes the write path state-size-free)
    existing = {}
    for f in forest.facts:
        if forest.fact_alive[f.fact_id]:
            existing[(_norm(f.subject), _norm(f.attribute), _norm(f.value), round(f.ts, 1))] = f

    for i, c in enumerate(candidates):
        key = (_norm(c.subject), _norm(c.attribute), _norm(c.value), round(c.ts, 1))
        if key in batch_seen:
            batch_seen[key].sources.append(c.source)
            continue
        if key in existing:
            existing[key].sources.append(c.source)
            continue
        fact = CanonicalFact(
            fact_id=-1,
            text=c.text,
            subject=c.subject.strip(),
            attribute=c.attribute.strip(),
            value=c.value.strip(),
            ts=c.ts,
            prev_value=c.prev_value,
            sources=[c.source],
            emb=embs[i] if embs is not None else None,
        )
        # embedding near-duplicate check within subject+attribute
        dup = None
        if embs is not None:
            for nf in new_facts:
                if (_norm(nf.subject), _norm(nf.attribute)) == key[:2] and \
                        float(nf.emb @ fact.emb) >= sim_threshold and \
                        _norm(nf.value) == key[2]:
                    dup = nf
                    break
        if dup is not None:
            dup.sources.append(c.source)
            continue
        batch_seen[key] = fact
        new_facts.append(fact)

    for f in new_facts:
        forest.add_fact(f)
    return new_facts
