"""Tiered hot/cold tenant residency with transparent rehydration (ROADMAP:
"'millions of users' cannot all hold device-resident indexes").

One :class:`ResidencyManager` owns a directory of per-tenant durable forests
(``<root>/<tenant_id>/`` — each a full ``DurableMemForest`` store) and keeps
at most ``hot_budget`` of them HOT: forest in memory, journal open, index
caches device-resident. Everything else is COLD: a compressed snapshot +
LATEST marker on disk (written by ``DurableMemForest.demote()``, a
checkpoint-class durable event) plus a tiny always-resident *digest* — the
tenant's root summaries and L2-normalized root embeddings.

The tiering is transparent at the API: ``ingest``/``query_batch`` on a cold
tenant rehydrate it with exactly ``DurableMemForest.open()`` (snapshot +
journal-tail replay — the same recovery path a crash takes, so durability
invariants hold across demotion by construction), and the forest's device
caches re-upload lazily on first index access. Eviction is traffic-aware
LRU: every touch bumps a tenant's exponentially-decayed heat, and when the
resident set exceeds the budget (count or estimated device bytes) the
lowest-heat resident is demoted. Under a ``ServeEngine`` the enforcement
runs on the maintenance plane between decode steps, so eviction never
blocks a decode.

Confidence-gated escalation (the MemoryAgent hot/cold/archive pattern): a
query against a cold tenant first scores against the digest. Only when the
best digest score clears ``digest_threshold`` — the sketch says the tenant
likely holds relevant memory — does the manager pay the full rehydration;
otherwise it answers from the digest directly (root-only-grade evidence,
zero device traffic), counted in ``digest_answers``.

The digest sidecar (``<tenant>/DIGEST``, msgpack + tagged compression,
tmp+fsync+rename durable) is DERIVED state, rebuilt at every demotion: a
stale or missing digest only affects escalation routing, never
correctness — with no digest a cold query always escalates.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import msgpack
import numpy as np

from repro import compression
from repro.config import MemForestConfig
from repro.core.journal import DurableMemForest, JOURNAL_NAME
from repro.core.retrieval import answer_query
from repro.core.types import CanonicalFact, QueryResult
from repro.data import templates as T
from repro.obs import Observability, get_obs
from repro.runtime import checkpoint as ckpt

DIGEST_NAME = "DIGEST"


@dataclass(frozen=True)
class ResidencyConfig:
    """Knobs for the hot/cold tenant tier.

    * ``hot_budget`` — max tenant forests resident at once.
    * ``device_budget_bytes`` — optional cap on the summed estimated device
      footprint of the resident set (0 = count budget only). Estimated as
      index rows x dim x 4B (``Forest.estimated_device_bytes``), so a hot
      tenant counts even before its caches materialize.
    * ``traffic_decay`` — per-touch multiplicative decay applied to every
      OTHER tenant's heat (exponential decay on a global touch clock);
      eviction picks the lowest effective heat, ties broken
      least-recently-touched.
    * ``digest_threshold`` — cold-query escalation gate: best digest score
      >= threshold pays the full rehydration, below it the digest answers.
      Set to a value > 1 to force digest answers, negative to force
      rehydration (queries always escalate when no digest exists).
    """
    hot_budget: int = 4
    device_budget_bytes: int = 0
    traffic_decay: float = 0.98
    digest_threshold: float = 0.35
    fsync: bool = False
    snapshot_every: int = 0
    keep_snapshots: int = 2


class TenantDigest:
    """The always-resident cold-tier sketch: one row per tree root —
    L2-normalized root embedding + root summary text. A few KB per tenant
    (vs MBs of index), so millions of cold tenants stay addressable."""

    __slots__ = ("emb", "texts")

    def __init__(self, emb: np.ndarray, texts: List[str]):
        self.emb = emb                    # (T, D) f32, L2-normalized rows
        self.texts = texts                # (T,) root summaries

    @classmethod
    def from_forest(cls, forest) -> "TenantDigest":
        rows: List[np.ndarray] = []
        texts: List[str] = []
        for scope_key in forest._tree_order:
            tree = forest.trees[scope_key]
            if tree.root < 0:
                continue
            e = tree.root_emb().astype(np.float32)
            rows.append(e / (np.linalg.norm(e) + 1e-6))
            texts.append(tree.text[tree.root][:200])
        dim = forest.config.embed_dim
        emb = np.stack(rows) if rows else np.zeros((0, dim), np.float32)
        return cls(emb, texts)

    def to_bytes(self) -> bytes:
        return compression.compress(msgpack.packb({
            "dim": int(self.emb.shape[1]) if self.emb.size else self.emb.shape[1],
            "emb": self.emb.astype(np.float32).tobytes(),
            "texts": self.texts,
        }, use_bin_type=True))

    @classmethod
    def from_bytes(cls, payload: bytes) -> "TenantDigest":
        doc = msgpack.unpackb(compression.decompress(payload), raw=False)
        dim = int(doc["dim"])
        emb = np.frombuffer(doc["emb"], np.float32).reshape(-1, dim).copy()
        return cls(emb, list(doc["texts"]))

    def nbytes(self) -> int:
        return int(self.emb.nbytes) + sum(len(t) for t in self.texts)


class _Tenant:
    __slots__ = ("tenant_id", "path", "store", "digest", "heat", "last_touch",
                 "demoted")

    def __init__(self, tenant_id: str, path: str):
        self.tenant_id = tenant_id
        self.path = path
        self.store: Optional[DurableMemForest] = None
        self.digest: Optional[TenantDigest] = None
        self.heat = 0.0                   # decayed at touch-clock resolution
        self.last_touch = 0               # global touch-clock stamp
        self.demoted = False              # demoted at least once (on disk)


class ResidencyManager:
    """Fixed device budget of hot tenant forests + transparent rehydration.

    ``auto_enforce=True`` (standalone use) demotes over-budget tenants at
    the end of every ingest/query call; a ``ServeEngine`` sets it False and
    drains ``enforce_budget`` on its maintenance cadence instead, so
    demotion work (snapshot + device free) never sits on the decode path.

    Thread-safe: one RLock guards the tenant table, so the maintenance
    plane's background thread can evict while the serve thread queries.
    ``crash=`` accepts a :class:`repro.runtime.fault_tolerance.CrashInjector`
    ticked at rehydration boundaries (demotion boundaries tick inside
    ``DurableMemForest.demote``), so the durability tests can kill the
    process mid-transition and assert digest-identical recovery.
    """

    def __init__(self, root_dir: str, *, config: Optional[ResidencyConfig] = None,
                 mem_config: Optional[MemForestConfig] = None, encoder=None,
                 kernel_impl: str = "reference", crash=None,
                 auto_enforce: bool = True,
                 obs: Optional[Observability] = None):
        from repro.core.encoder import HashingEncoder

        self.root = root_dir
        self.config = config or ResidencyConfig()
        self.mem_config = mem_config or MemForestConfig()
        # ONE encoder shared by every tenant store and the digest gate —
        # encoders are stateless apart from call/token counters
        self.encoder = encoder or HashingEncoder(dim=self.mem_config.embed_dim)
        self.kernel_impl = kernel_impl
        self.crash = crash
        self.auto_enforce = auto_enforce
        self.lock = threading.RLock()
        self._tenants: Dict[str, _Tenant] = {}
        self._clock = 0
        # counters live in the registry (residency/* namespace); the legacy
        # attribute names (engine metrics + benchmarks read these) come back
        # through properties and metrics() reports from the registry.
        # Demote/rehydrate/digest-answer each run under a span.
        self.obs = get_obs(obs)
        reg = self.obs.registry
        self._m_evictions = reg.counter("residency/evictions")
        self._m_rehydrations = reg.counter("residency/rehydrations")
        self._m_digest_answers = reg.counter("residency/digest_answers")
        self._m_digest_escalations = reg.counter("residency/digest_escalations")
        self._m_bytes_released = reg.counter("residency/bytes_released")
        os.makedirs(root_dir, exist_ok=True)
        self._scan_existing()

    # ------------------------------------------------------------------
    # registry-backed legacy counters (attribute back-compat)
    # ------------------------------------------------------------------
    @property
    def evictions(self) -> int:
        return self._m_evictions.value

    @property
    def rehydrations(self) -> int:
        return self._m_rehydrations.value

    @property
    def digest_answers(self) -> int:
        return self._m_digest_answers.value

    @property
    def digest_escalations(self) -> int:
        return self._m_digest_escalations.value

    @property
    def bytes_released(self) -> int:
        return self._m_bytes_released.value

    # ------------------------------------------------------------------
    # tenant table
    # ------------------------------------------------------------------
    def _scan_existing(self) -> None:
        """Register on-disk tenants as COLD entries (digest loaded when the
        sidecar exists) — a restarted manager resumes with every tenant
        addressable and zero device bytes."""
        for name in sorted(os.listdir(self.root)):
            p = os.path.join(self.root, name)
            if not os.path.isdir(p):
                continue
            if not (ckpt.read_latest(p)
                    or os.path.exists(os.path.join(p, JOURNAL_NAME))):
                continue
            t = _Tenant(name, p)
            t.demoted = True
            dpath = os.path.join(p, DIGEST_NAME)
            if os.path.exists(dpath):
                with open(dpath, "rb") as f:
                    t.digest = TenantDigest.from_bytes(f.read())
            self._tenants[name] = t

    def _get(self, tenant_id: str) -> _Tenant:
        t = self._tenants.get(tenant_id)
        if t is None:
            if os.sep in tenant_id or tenant_id in ("", ".", ".."):
                raise ValueError(f"tenant id {tenant_id!r} is not a valid "
                                 "directory name")
            t = _Tenant(tenant_id, os.path.join(self.root, tenant_id))
            self._tenants[tenant_id] = t
        return t

    def _touch(self, t: _Tenant) -> None:
        self._clock += 1
        t.heat = self._effective_heat(t) + 1.0
        t.last_touch = self._clock

    def _effective_heat(self, t: _Tenant) -> float:
        return t.heat * self.config.traffic_decay ** (self._clock - t.last_touch)

    def _tick(self, event: str) -> None:
        if self.crash is not None:
            self.crash.tick(event)

    # ------------------------------------------------------------------
    # residency transitions
    # ------------------------------------------------------------------
    def _rehydrate(self, t: _Tenant) -> None:
        """Cold -> hot: exactly the crash-recovery open (snapshot +
        journal-tail replay). Device caches re-upload lazily on the first
        index access, so only THIS tenant's rows ever transfer."""
        was_cold = t.demoted or ckpt.read_latest(t.path) is not None \
            or os.path.exists(os.path.join(t.path, JOURNAL_NAME))
        with self.obs.span("residency.rehydrate", tenant=t.tenant_id):
            self._tick("rehydrate:begin")
            cfg = self.config
            store = DurableMemForest.open(
                t.path, config=self.mem_config, encoder=self.encoder,
                kernel_impl=self.kernel_impl, fsync=cfg.fsync,
                snapshot_every=cfg.snapshot_every, crash=self.crash,
                keep_snapshots=cfg.keep_snapshots, obs=self.obs)
            t.store = store
            self._tick("rehydrate:commit")
        if was_cold:
            self._m_rehydrations.inc()
        t.demoted = False

    def _demote(self, t: _Tenant) -> None:
        """Hot -> cold: flush pending derived work, rebuild + durably write
        the digest sidecar, then the checkpoint-class demotion (snapshot +
        LATEST flip + journal rotation + device-cache free)."""
        store = t.store
        assert store is not None
        freed = self._footprint(t)
        with self.obs.span("residency.demote", tenant=t.tenant_id,
                           bytes=freed):
            if store.forest.dirty_trees:
                # digest + snapshot must capture fresh root summaries; flush
                # is derived-only work (never journaled), safe at any point
                store.forest.flush()
            digest = TenantDigest.from_forest(store.forest)
            self._tick("demote:digest")
            self._write_digest(t, digest)
            store.demote()                # ticks demote:begin/commit inside
            store.close()
        t.store = None
        t.digest = digest
        t.demoted = True
        self._m_evictions.inc()
        self._m_bytes_released.inc(freed)

    def _write_digest(self, t: _Tenant, digest: TenantDigest) -> None:
        path = os.path.join(t.path, DIGEST_NAME)
        os.makedirs(t.path, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(digest.to_bytes())
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        ckpt.fsync_dir(t.path)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def acquire(self, tenant_id: str) -> DurableMemForest:
        """Touch + return the tenant's hot store, rehydrating if cold. Does
        NOT enforce the budget — callers (or the maintenance drain) do."""
        with self.lock:
            t = self._get(tenant_id)
            self._touch(t)
            if t.store is None:
                self._rehydrate(t)
            return t.store

    def ingest(self, tenant_id: str, sessions, *,
               idempotency_key: Optional[str] = None,
               defer_flush: bool = False):
        """Durable exactly-once ingest on the tenant's journal (rehydrates
        a cold tenant first — writes always land in the real store)."""
        with self.lock:
            store = self.acquire(tenant_id)
            out = store.ingest_batch(sessions, idempotency_key=idempotency_key,
                                     defer_flush=defer_flush)
        if self.auto_enforce:
            self.enforce_budget()
        return out

    def query_batch(self, tenant_id: str, queries, *, mode: Optional[str] = None,
                    final_topk: Optional[int] = None) -> List[QueryResult]:
        """Tiered read path. Hot tenant: the normal batched query. Cold
        tenant: digest gate first — escalate (rehydrate + full query) only
        when the digest's best score clears the threshold, else answer from
        the digest (mode is moot there: the digest IS root-only evidence)."""
        with self.lock:
            t = self._get(tenant_id)
            self._touch(t)
            if t.store is None:
                res = self._digest_answer(t, queries, final_topk)
                if res is not None:
                    self._m_digest_answers.inc(len(queries))
                    return res
                if t.digest is not None and t.digest.emb.shape[0]:
                    self._m_digest_escalations.inc()
                self._rehydrate(t)
            out = t.store.query_batch(queries, mode=mode, final_topk=final_topk)
        if self.auto_enforce:
            self.enforce_budget()
        return out

    def query(self, tenant_id: str, q, *, mode: Optional[str] = None,
              final_topk: Optional[int] = None) -> QueryResult:
        return self.query_batch(tenant_id, [q], mode=mode,
                                final_topk=final_topk)[0]

    def demote(self, tenant_id: str) -> bool:
        """Explicitly demote one tenant (True if it was resident)."""
        with self.lock:
            t = self._tenants.get(tenant_id)
            if t is None or t.store is None:
                return False
            self._demote(t)
            return True

    def state_digest(self, tenant_id: str) -> str:
        """Persistent-state identity hash for one tenant (rehydrates)."""
        return self.acquire(tenant_id).state_digest()

    # ------------------------------------------------------------------
    # budget enforcement (traffic-aware LRU)
    # ------------------------------------------------------------------
    def _residents(self) -> List[_Tenant]:
        return [t for t in self._tenants.values() if t.store is not None]

    def _footprint(self, t: _Tenant) -> int:
        f = t.store.forest
        return max(f.device_bytes(), f.estimated_device_bytes())

    def over_budget(self) -> int:
        """How many demotions the budget currently calls for (0 = within)."""
        with self.lock:
            res = self._residents()
            over = max(0, len(res) - self.config.hot_budget)
            cap = self.config.device_budget_bytes
            if cap and len(res) > 1:
                total = sum(self._footprint(t) for t in res)
                sized = sorted((self._footprint(t) for t in res), reverse=True)
                n = 0
                while total > cap and n < len(sized) - 1:
                    total -= sized[n]
                    n += 1
                over = max(over, n)
            return over

    def enforce_budget(self, max_demotions: Optional[int] = None) -> int:
        """Demote lowest-heat residents until within budget (or the per-call
        cap — the engine passes its maintenance budget so one drain turn
        stays bounded). Returns demotions performed."""
        done = 0
        with self.lock:
            while self.over_budget() and (max_demotions is None
                                          or done < max_demotions):
                res = self._residents()
                if len(res) <= 1 and len(res) <= self.config.hot_budget:
                    break
                victim = min(res, key=lambda t: (self._effective_heat(t),
                                                 t.last_touch, t.tenant_id))
                self._demote(victim)
                done += 1
        return done

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def is_resident(self, tenant_id: str) -> bool:
        with self.lock:
            t = self._tenants.get(tenant_id)
            return t is not None and t.store is not None

    def tenant_ids(self) -> List[str]:
        with self.lock:
            return sorted(self._tenants)

    def metrics(self) -> Dict[str, Any]:
        """Legacy keys, reported through the registry (the transition
        counters behind the properties ARE registry counters)."""
        with self.lock:
            res = self._residents()
            return {
                "tenants": len(self._tenants),
                "hot_tenants": len(res),
                "cold_tenants": len(self._tenants) - len(res),
                "hot_budget": self.config.hot_budget,
                "evictions": self._m_evictions.value,
                "rehydrations": self._m_rehydrations.value,
                "digest_answers": self._m_digest_answers.value,
                "digest_escalations": self._m_digest_escalations.value,
                "device_bytes": sum(t.store.forest.device_bytes()
                                    for t in res),
                "device_bytes_est": sum(self._footprint(t) for t in res),
                "digest_bytes": sum(t.digest.nbytes()
                                    for t in self._tenants.values()
                                    if t.digest is not None),
                "bytes_released": self._m_bytes_released.value,
            }

    def close(self) -> None:
        """Close every hot store's journal (no demotion — state stays hot
        on disk exactly as the journal + last snapshot describe it)."""
        with self.lock:
            for t in self._tenants.values():
                if t.store is not None:
                    t.store.close()
                    t.store = None

    def __enter__(self) -> "ResidencyManager":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ------------------------------------------------------------------
    # digest gate
    # ------------------------------------------------------------------
    def _digest_answer(self, t: _Tenant, queries,
                       final_topk: Optional[int]) -> Optional[List[QueryResult]]:
        """Score the batch against the tenant digest. Returns answers when
        the best score stays BELOW the escalation threshold (low confidence
        that rehydration would surface more than the digest already holds);
        None means escalate — also when no digest exists (unknown tenant
        content must not be answered from nothing)."""
        digest = t.digest
        if digest is None or digest.emb.shape[0] == 0:
            return None
        with self.obs.span("residency.digest_answer", tenant=t.tenant_id,
                           queries=len(queries)) as sp:
            return self._digest_answer_scored(t, queries, final_topk, sp)

    def _digest_answer_scored(self, t: _Tenant, queries,
                              final_topk: Optional[int],
                              sp) -> Optional[List[QueryResult]]:
        digest = t.digest
        t0 = time.perf_counter()
        calls0 = self.encoder.stats.calls
        q_embs = self.encoder.encode([q.text for q in queries])
        qn = q_embs / (np.linalg.norm(q_embs, axis=-1, keepdims=True) + 1e-6)
        sims = qn @ digest.emb.T                      # (Q, T)
        if float(sims.max()) >= self.config.digest_threshold:
            sp.set(answered=False)                    # escalating
            return None
        sp.set(answered=True)
        topk = final_topk or self.mem_config.final_topk
        rows_k = min(self.mem_config.forest_recall_topk, digest.emb.shape[0])
        out: List[QueryResult] = []
        t1 = time.perf_counter()
        for qi, q in enumerate(queries):
            order = np.argsort(-sims[qi], kind="stable")[:rows_k]
            evidence = [digest.texts[i] for i in order]
            facts: List[CanonicalFact] = []
            for i in order:
                # same lossy summary re-extraction as root-only mode
                # (retrieval._facts_from_summaries)
                for cand in T.parse_statement(digest.texts[i], ("digest", 0)):
                    facts.append(CanonicalFact(
                        fact_id=-1, text=cand.text, subject=cand.subject,
                        attribute=cand.attribute, value=cand.value, ts=cand.ts,
                        prev_value=cand.prev_value, sources=[cand.source],
                        emb=None))
            out.append(QueryResult(
                answer=answer_query(q, facts[:topk]),
                evidence=evidence,
                retrieval_s=(t1 - t0) / max(len(queries), 1),
                answer_s=(time.perf_counter() - t1) / max(len(queries), 1),
                encoder_calls=self.encoder.stats.calls - calls0,
            ))
        return out
