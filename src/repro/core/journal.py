"""Write-ahead journal + durable exactly-once write path (ROADMAP: "async
maintenance plane + durable, idempotent ingest").

The serve loop's lifecycle writes (``ingest_batch``, ``delete_session``,
``migrate_merge``) are record-then-apply: every op is framed into an
append-only journal — WITH a client-supplied idempotency key — before it
touches the Forest. Durability story:

  * **crash mid-op**: the in-memory forest is gone either way; recovery is
    latest snapshot + replay of the journal tail. A record appended but
    never applied replays once; an op that crashed before its append was
    never acknowledged and the client retries it.
  * **duplicated webhook delivery**: a key already in ``forest.applied_ops``
    (persisted inside every snapshot) is skipped before it reaches the
    journal — replayed deliveries are exactly-once end to end.
  * **snapshot + tail**: ``checkpoint()`` writes an atomic snapshot tagged
    with the journal sequence watermark (via the same LATEST-marker commit
    protocol as runtime/checkpoint.py), then rotates the journal; replay
    applies only records past the watermark whose key is unapplied.

Journal format: back-to-back frames, each ``<u32 body_len, u32 crc32>`` +
msgpack body ``{seq, op, key, payload}``. A torn tail frame (crash mid-
append) fails its length or CRC check and cleanly ends replay; recovery
then truncates the file to its valid prefix, so frames appended after the
crash never sit behind garbage bytes (which would make them fsync-acked
yet invisible to every later scan).

Fault injection: a :class:`repro.runtime.fault_tolerance.CrashInjector`
passed as ``crash=`` gets a ``tick()`` at every durability transition, so
tests can kill the "process" at every boundary and assert recovered state
is digest-identical to an uninterrupted run (tests/test_durability.py).
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Any, Dict, Iterable, List, Optional, Tuple

import msgpack

from repro.core import maintenance, persistence
from repro.core.types import Session, Turn
from repro.obs import Observability, get_obs
from repro.runtime import checkpoint as ckpt

_FRAME_HEADER = struct.Struct("<II")          # (body_len, crc32)
JOURNAL_NAME = "journal.waj"
SNAPSHOT_FMT = "snapshot_{:08d}.mfz"


# ---------------------------------------------------------------------------
# framed append-only journal
# ---------------------------------------------------------------------------
class JournalWriter:
    """Append-only framed record log. ``fsync=True`` makes every append a
    durability point (webhook-ack semantics); ``fsync=False`` leaves
    flush-to-OS group commit (bench mode — a crash can lose the tail but
    never tear the exactly-once contract, because unacked ops are retried
    by the client and deduped by key)."""

    def __init__(self, path: str, *, fsync: bool = True,
                 obs: Optional[Observability] = None):
        self.path = path
        self.fsync = fsync
        self.obs = get_obs(obs)
        self._m_appends = self.obs.registry.counter("journal/appends")
        self._m_bytes = self.obs.registry.counter("journal/appended_bytes")
        existed = os.path.exists(path)
        self._f = open(path, "ab")
        if fsync and not existed:
            # a fresh journal's directory entry must be durable too, or the
            # first acked append can vanish with the file on power loss
            ckpt.fsync_dir(os.path.dirname(os.path.abspath(path)))

    @property
    def appends(self) -> int:
        return self._m_appends.value

    def append(self, record: Dict[str, Any]) -> None:
        body = msgpack.packb(record, use_bin_type=True)
        with self.obs.span("journal.append",
                           bytes=_FRAME_HEADER.size + len(body)):
            self._f.write(_FRAME_HEADER.pack(len(body), zlib.crc32(body)))
            self._f.write(body)
            self._f.flush()
            if self.fsync:
                with self.obs.span("journal.fsync"):
                    os.fsync(self._f.fileno())
        self._m_appends.inc()
        self._m_bytes.inc(_FRAME_HEADER.size + len(body))

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def scan_journal(path: str) -> Tuple[List[Dict[str, Any]], int]:
    """(complete records, byte length of the valid prefix). A torn/corrupt
    tail frame ends the scan; recovery truncates the file to the returned
    offset so new appends never land after garbage bytes."""
    if not os.path.exists(path):
        return [], 0
    out: List[Dict[str, Any]] = []
    with open(path, "rb") as f:
        data = f.read()
    pos = 0
    while pos + _FRAME_HEADER.size <= len(data):
        length, crc = _FRAME_HEADER.unpack_from(data, pos)
        body = data[pos + _FRAME_HEADER.size: pos + _FRAME_HEADER.size + length]
        if len(body) < length or zlib.crc32(body) != crc:
            break                                   # torn tail
        out.append(msgpack.unpackb(body, raw=False))
        pos += _FRAME_HEADER.size + length
    return out, pos


def read_journal(path: str) -> List[Dict[str, Any]]:
    """All complete records; a torn/corrupt tail frame ends the scan."""
    return scan_journal(path)[0]


# ---------------------------------------------------------------------------
# op payload (de)serialization
# ---------------------------------------------------------------------------
def _session_rec(s: Session) -> Dict[str, Any]:
    return {"id": s.session_id, "ts": s.ts,
            "turns": [[t.role, t.text, t.ts, t.turn_id] for t in s.turns]}


def _session_from(rec: Dict[str, Any]) -> Session:
    return Session(rec["id"],
                   [Turn(role=r, text=x, ts=ts, turn_id=tid)
                    for r, x, ts, tid in rec["turns"]],
                   ts=rec["ts"])


# ---------------------------------------------------------------------------
# durable store
# ---------------------------------------------------------------------------
class DurableMemForest:
    """Durability shell around a :class:`MemForestSystem`.

    Directory layout::

        <root>/journal.waj             append-only op log (rotated)
        <root>/snapshot_<seq>.mfz      atomic forest snapshots
        <root>/LATEST                  current-snapshot marker

    Open an existing store (or a fresh directory) with :meth:`open` — it
    performs snapshot + journal-tail recovery. ``snapshot_every=N`` takes an
    automatic checkpoint after every N applied ops (0 = manual only).
    """

    def __init__(self, system, root_dir: str, *, fsync: bool = True,
                 snapshot_every: int = 0, crash=None, keep_snapshots: int = 2,
                 _next_seq: int = 1, obs: Optional[Observability] = None):
        self.system = system
        self.root = root_dir
        os.makedirs(root_dir, exist_ok=True)
        self.crash = crash
        self.snapshot_every = snapshot_every
        self.keep_snapshots = keep_snapshots
        self._seq = _next_seq
        # share the wrapped system's observability handle unless given one,
        # so journal/* metrics and span histograms land in the same registry
        # the forest/flush instrumentation reports to
        self.obs = obs if obs is not None else get_obs(
            getattr(system, "obs", None))
        self.writer = JournalWriter(os.path.join(root_dir, JOURNAL_NAME),
                                    fsync=fsync, obs=self.obs)
        self._m_commits = self.obs.registry.counter("journal/commits")
        self._m_checkpoints = self.obs.registry.counter("journal/checkpoints")
        # counters
        self.ops_applied = 0
        self.duplicates_skipped = 0
        self.ops_replayed = 0
        self.snapshots_taken = 0
        self._ops_since_snapshot = 0

    # -- plumbing ----------------------------------------------------------
    @property
    def forest(self):
        return self.system.forest

    def _tick(self, event: str) -> None:
        if self.crash is not None:
            self.crash.tick(event)

    def _already_applied(self, key: Optional[str]) -> bool:
        if key is not None and key in self.forest.applied_ops:
            self.duplicates_skipped += 1
            return True
        return False

    def _record(self, op: str, key: Optional[str], payload: Dict[str, Any]) -> str:
        """Append the intent frame; returns the (possibly auto) key."""
        seq = self._seq
        self._seq += 1
        if key is None:
            # auto keys are unique, so they never dedup client retries —
            # they exist so replay bookkeeping is uniform for callers that
            # did not supply one
            key = f"auto:{op}:{seq}"
        self._tick(f"submit:{op}")
        self.writer.append({"seq": seq, "op": op, "key": key,
                            "payload": payload})
        self._tick("journal:append")
        return key

    def _committed(self, key: str) -> None:
        self.forest.applied_ops.add(key)
        self.ops_applied += 1
        self._m_commits.inc()
        self._ops_since_snapshot += 1
        self.obs.event("journal.commit", key=key)
        self._tick("apply")
        if self.snapshot_every and self._ops_since_snapshot >= self.snapshot_every:
            self.checkpoint()

    # -- the durable write path -------------------------------------------
    def ingest_batch(self, sessions: Iterable[Session], *,
                     idempotency_key: Optional[str] = None,
                     defer_flush: bool = False):
        """Journaled, exactly-once ``MemForestSystem.ingest_batch``. Returns
        the per-session WriteStats, or None when the key was already
        applied (duplicate delivery)."""
        sessions = list(sessions)
        if self._already_applied(idempotency_key):
            return None
        key = self._record("ingest_batch", idempotency_key,
                           {"sessions": [_session_rec(s) for s in sessions]})
        stats = self.system.ingest_batch(sessions, defer_flush=defer_flush)
        self._committed(key)
        return stats

    def delete_session(self, session_id: str, *,
                       idempotency_key: Optional[str] = None,
                       flush: bool = True):
        """Journaled, exactly-once targeted deletion."""
        if self._already_applied(idempotency_key):
            return None
        key = self._record("delete_session", idempotency_key,
                           {"session_id": session_id})
        out = maintenance.delete_session(self.forest, session_id, flush=flush)
        self._committed(key)
        return out

    def merge_from(self, other, *, idempotency_key: Optional[str] = None,
                   flush: bool = True):
        """Journaled, exactly-once migration merge. ``other`` is a
        MemForestSystem or a bare Forest; its full state rides in the
        journal record, so replay reproduces the merge byte-identically
        even if the source forest is gone by recovery time."""
        if self._already_applied(idempotency_key):
            return None
        src = getattr(other, "forest", other)
        doc_z = persistence.doc_to_bytes(
            persistence.forest_to_doc(src, with_derived=True))
        key = self._record("migrate_merge", idempotency_key,
                           {"forest_doc_z": doc_z})
        out = maintenance.migrate_merge(self.forest, src, flush=flush)
        self._committed(key)
        return out

    def compact_tree(self, scope_key: str, *,
                     idempotency_key: Optional[str] = None):
        """Journaled tombstone compaction. Compaction rewrites persistent
        state (the tree arena and its placement rows), so it must ride the
        journal like any other lifecycle write — otherwise a crash after an
        unjournaled compaction recovers to a different state digest than the
        pre-crash store. Rebuild is deterministic (live leaves re-inserted
        in time order), so replay reproduces it exactly."""
        if self._already_applied(idempotency_key):
            return None
        key = self._record("compact_tree", idempotency_key,
                           {"scope_key": scope_key})
        out = maintenance.compact_tree(self.forest, scope_key)
        self._committed(key)
        return out

    # -- replay ------------------------------------------------------------
    def _apply_record(self, rec: Dict[str, Any]) -> None:
        op, payload = rec["op"], rec["payload"]
        if op == "ingest_batch":
            self.system.ingest_batch(
                [_session_from(r) for r in payload["sessions"]])
        elif op == "delete_session":
            maintenance.delete_session(self.forest, payload["session_id"])
        elif op == "migrate_merge":
            src = persistence.forest_from_doc(
                persistence.bytes_to_doc(payload["forest_doc_z"]),
                kernel_impl=self.forest.kernel_impl)
            maintenance.migrate_merge(self.forest, src)
        elif op == "compact_tree":
            maintenance.compact_tree(self.forest, payload["scope_key"])
        else:
            raise ValueError(f"unknown journal op {op!r}")
        self.forest.applied_ops.add(rec["key"])
        self.ops_replayed += 1

    # -- snapshot + rotation ----------------------------------------------
    def checkpoint(self, *, residency: Optional[Dict[str, Any]] = None) -> str:
        """Snapshot current state (tagged with the journal watermark), move
        the LATEST marker, rotate the journal. Crash-safe at every step:
        the snapshot write is tmp+rename-atomic, the marker flips last, and
        un-rotated journal records are filtered by the watermark on
        replay.

        ``residency`` (persistence doc v3) rides in the snapshot's ``extra``
        — the demotion record written by :meth:`demote`. It is excluded from
        ``forest_state_digest`` like the rest of ``extra``, so residency
        transitions never perturb state identity."""
        with self.obs.span("journal.checkpoint",
                           watermark=self._seq - 1):
            return self._checkpoint(residency=residency)

    def _checkpoint(self, *, residency: Optional[Dict[str, Any]] = None) -> str:
        self._tick("snapshot:begin")
        watermark = self._seq - 1
        name = SNAPSHOT_FMT.format(watermark)
        extra: Dict[str, Any] = {"journal_seq": watermark}
        if residency is not None:
            extra["residency"] = residency
        persistence.save_forest(self.forest, os.path.join(self.root, name),
                                extra=extra)
        ckpt.write_latest(self.root, name)
        self._tick("snapshot:commit")
        # rotate: atomically replace the journal with an empty file — every
        # framed record is <= the watermark now
        self.writer.close()
        jpath = os.path.join(self.root, JOURNAL_NAME)
        tmp = jpath + ".tmp"
        with open(tmp, "wb") as f:
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, jpath)
        ckpt.fsync_dir(self.root)
        self.writer = JournalWriter(jpath, fsync=self.writer.fsync,
                                    obs=self.obs)
        self._tick("journal:rotate")
        # GC old snapshots (keep the newest keep_snapshots; the one the
        # LATEST marker points at is always kept). snaps[:-k] would be wrong
        # for k=0 — it keeps everything instead of nothing.
        snaps = sorted(n for n in os.listdir(self.root)
                       if n.startswith("snapshot_") and n.endswith(".mfz"))
        for n in snaps[:max(0, len(snaps) - self.keep_snapshots)]:
            if n != name:
                os.remove(os.path.join(self.root, n))
        self.snapshots_taken += 1
        self._m_checkpoints.inc()
        self._ops_since_snapshot = 0
        return name

    def demote(self) -> Tuple[str, int]:
        """Tenant demotion as a **checkpoint-class** durable event: snapshot
        (with a residency record in the doc's ``extra``) + rotate, then free
        the device index caches. Returns (snapshot name, device bytes freed).

        Deliberately NOT a journal op: journal records carry idempotency
        keys into ``forest.applied_ops`` (and thus the state digest), so a
        journaled demote retried across a crash would make recovered state
        identity depend on how many times the demotion was attempted. A
        checkpoint changes no persistent state, so a demote interrupted at
        ANY boundary (``demote:begin`` .. ``demote:commit``) recovers
        digest-identical and is safely retried whole. Rehydration afterwards
        is exactly :meth:`open` — snapshot + (empty, just-rotated) journal
        tail + transparent device re-upload on first index access."""
        self._tick("demote:begin")
        name = self.checkpoint(residency={"demoted": True,
                                          "journal_seq": self._seq - 1})
        freed = self.forest.detach_device()
        self._tick("demote:commit")
        return name, freed

    def close(self) -> None:
        self.writer.close()

    def __enter__(self) -> "DurableMemForest":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- recovery ----------------------------------------------------------
    @classmethod
    def open(cls, root_dir: str, *, config=None, encoder=None,
             kernel_impl: str = "reference", fsync: bool = True,
             snapshot_every: int = 0, crash=None,
             keep_snapshots: int = 2,
             obs: Optional[Observability] = None) -> "DurableMemForest":
        """Crash-safe restore: latest snapshot (if any) + journal-tail
        replay. Records at or below the snapshot watermark, or whose
        idempotency key the snapshot already carries, are skipped —
        duplicated or crash-replayed ops apply exactly once."""
        from repro.core.memforest import MemForestSystem

        os.makedirs(root_dir, exist_ok=True)
        watermark = 0
        name = ckpt.read_latest(root_dir)
        snap_path = os.path.join(root_dir, name) if name else None
        if snap_path and os.path.exists(snap_path):
            doc = persistence.read_doc(snap_path)
            forest = persistence.forest_from_doc(doc, config,
                                                 kernel_impl=kernel_impl)
            watermark = int(doc.get("extra", {}).get("journal_seq", 0))
            system = MemForestSystem(forest.config, encoder,
                                     kernel_impl=kernel_impl, obs=obs)
            forest.obs = system.obs     # restored forest joins our registry
            system.forest = forest
            system.retriever.forest = forest
            system.batcher.forest = forest
        else:
            system = MemForestSystem(config, encoder, kernel_impl=kernel_impl,
                                     obs=obs)

        jpath = os.path.join(root_dir, JOURNAL_NAME)
        records, valid_len = scan_journal(jpath)
        if os.path.exists(jpath) and os.path.getsize(jpath) > valid_len:
            # crash mid-append left a torn tail frame. It MUST be cut before
            # the writer reopens in append mode: frames written after the
            # garbage would be fsync-acked yet unreachable — every later
            # recovery stops scanning at the torn frame and silently drops
            # them, breaking the exactly-once contract.
            with open(jpath, "rb+") as f:
                f.truncate(valid_len)
                f.flush()
                os.fsync(f.fileno())
        next_seq = max([watermark] + [r["seq"] for r in records]) + 1
        store = cls(system, root_dir, fsync=fsync,
                    snapshot_every=snapshot_every, crash=crash,
                    keep_snapshots=keep_snapshots, _next_seq=next_seq,
                    obs=obs)
        for rec in records:
            if rec["seq"] <= watermark:
                continue
            if rec["key"] in store.forest.applied_ops:
                continue
            store._apply_record(rec)
        return store

    # everything else (query, query_batch, scale_stats, save, ...) is
    # read-only or derived-state work — delegate to the wrapped system
    def __getattr__(self, item):
        return getattr(self.system, item)
