"""Lifecycle maintenance: merge, delete, migration (paper §4.4, §5.6).

All operations edit PERSISTENT state first (facts, scope assignments, tree
structure, placement maps), then regenerate only derived artifacts whose
dependency paths intersect the affected scopes — via the same lazy
dirty-path flush as normal ingestion.

Migration merge is the paper's Figure-5 experiment: already-materialized
memory states combine WITHOUT replaying raw sessions through extraction.
Matching scopes bulk-insert the other forest's leaves (dirty paths only);
unmatched trees are copied verbatim — their derived artifacts remain valid
and are NOT recomputed, which is where the >2x speedup comes from.
"""
from __future__ import annotations

import copy
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.forest import Forest
from repro.core.memtree import TreeArena
from repro.core.types import CanonicalFact


def delete_session(forest: Forest, session_id: str, *,
                   flush: bool = True) -> Dict[str, int]:
    """Targeted deletion: the session registry identifies derived facts,
    cells, and tree leaves; only invalidated ancestor paths refresh.

    ``flush=False`` leaves the invalidated paths in ``forest.dirty_trees``
    for the maintenance plane (or the next reader) to refresh — persistent
    state is fully updated either way."""
    reg = forest.session_registry.get(session_id)
    if not reg:
        return {"facts_removed": 0, "leaves_removed": 0}
    leaves_removed = 0
    facts_removed = 0
    for fid in reg["facts"]:
        fact = forest.facts[fid]
        fact.sources = [s for s in fact.sources if s[0] != session_id]
        if fact.sources:
            continue  # still supported by other sessions
        forest.kill_fact(fid)        # dead rows go inert (host + device index)
        facts_removed += 1
        for scope_key, leaf in forest.placement.pop(("fact", fid), []):
            tree = forest.trees[scope_key]
            if tree.alive[leaf]:
                tree.delete_leaf(leaf)
                leaves_removed += 1
                forest.dirty_trees.add(scope_key)
    for cid in reg["cells"]:
        for scope_key, leaf in forest.placement.pop(("cell", cid), []):
            tree = forest.trees[scope_key]
            if tree.alive[leaf]:
                tree.delete_leaf(leaf)
                leaves_removed += 1
                forest.dirty_trees.add(scope_key)
    forest.session_registry.pop(session_id, None)
    if flush:
        forest.flush()
    return {"facts_removed": facts_removed, "leaves_removed": leaves_removed}


def _merge_sources(dst_sources: List[Tuple[str, int]],
                   new_sources: List[Tuple[str, int]]) -> int:
    """Union provenance on (session_id, chunk) — appending without dedup
    made re-running a merge (the journal-retry case) duplicate sources and
    skew session-registry deletion. Returns sources actually added."""
    seen = set(map(tuple, dst_sources))
    added = 0
    for s in new_sources:
        s = tuple(s)
        if s not in seen:
            seen.add(s)
            dst_sources.append(s)
            added += 1
    return added


def _copy_tree_into(dst: Forest, src_tree: TreeArena, scope_key: str,
                    fact_id_map: Dict[int, int], cell_id_map: Dict[int, int]) -> None:
    """Verbatim structural copy (derived artifacts stay valid — no refresh)."""
    t = dst.get_tree(scope_key, src_tree.kind)
    assert t.root < 0, "copy target must be empty"
    n = src_tree._n
    t.parent = list(src_tree.parent)
    t.children = [list(c) for c in src_tree.children]
    t.level = list(src_tree.level)
    t.start_ts = list(src_tree.start_ts)
    t.end_ts = list(src_tree.end_ts)
    t.text = list(src_tree.text)
    t.alive = list(src_tree.alive)
    t.payload = []
    for p in src_tree.payload:
        if p is None:
            t.payload.append(None)
        elif p >= 0:
            t.payload.append(fact_id_map[p])
        else:
            t.payload.append(-cell_id_map[-p - 1] - 1)
    t.emb = src_tree.emb[:max(n, 8)].copy()
    t.root = src_tree.root
    t._n = n
    # a src serialized under deferred flush carries dirty paths whose copied
    # summaries are stale — propagate the marks so they still refresh
    t.dirty = set(src_tree.dirty)
    if t.dirty:
        dst.dirty_trees.add(scope_key)
    # placement rows for the copied leaves
    for nid in range(n):
        if t.alive[nid] and t.level[nid] == 0 and t.payload[nid] is not None:
            p = t.payload[nid]
            if p >= 0:
                dst.placement.setdefault(("fact", p), []).append((scope_key, nid))
            else:
                dst.placement.setdefault(("cell", -p - 1), []).append((scope_key, nid))
    dst.set_root_row(t)


def migrate_merge(dst: Forest, src: Forest, *,
                  idempotency_key: Optional[str] = None,
                  flush: bool = True) -> Dict[str, int]:
    """Merge an already-materialized forest into `dst` (paper Fig. 5).

    1. Reconcile canonical facts (key-dedup; sources union on
       (session_id, chunk) — re-running a merge never duplicates
       provenance).
    2. Matching scopes: bulk time-ordered insert of src leaves -> dirty paths.
    3. Unmatched trees: verbatim copy, NO derived-artifact regeneration.
    4. One lazy flush over dirty paths (deferrable via ``flush=False``).

    ``idempotency_key``: when given, the merge is exactly-once — a key
    already in ``dst.applied_ops`` (persisted in snapshots) makes the call
    a no-op, so journal replay or a duplicated merge webhook cannot
    double-insert leaves or registry rows.
    """
    stats = {"facts_added": 0, "facts_merged": 0, "trees_copied": 0,
             "trees_merged": 0, "skipped_duplicate": 0}
    if idempotency_key is not None:
        if idempotency_key in dst.applied_ops:
            stats["skipped_duplicate"] = 1
            return stats
        dst.applied_ops.add(idempotency_key)

    def key(f: CanonicalFact):
        return (f.subject.lower(), f.attribute, f.value.lower(), round(f.ts, 1))

    existing = {key(f): f.fact_id for f in dst.facts if dst.fact_alive[f.fact_id]}
    fact_id_map: Dict[int, int] = {}
    for f in src.facts:
        if not src.fact_alive[f.fact_id]:
            continue
        k = key(f)
        if k in existing:
            _merge_sources(dst.facts[existing[k]].sources, f.sources)
            fact_id_map[f.fact_id] = existing[k]
            stats["facts_merged"] += 1
        else:
            nf = copy.copy(f)
            nf.sources = []
            _merge_sources(nf.sources, f.sources)
            nid = dst.add_fact(nf)
            fact_id_map[f.fact_id] = nid
            stats["facts_added"] += 1

    cell_id_map: Dict[int, int] = {}
    for c in src.cells:
        nc = copy.copy(c)
        cell_id_map[c.cell_id] = dst.add_cell(nc)

    # scene scopes: cluster ids are forest-local, so match src scenes to dst
    # scenes by centroid similarity (>= threshold merges into the existing
    # scene tree; below it becomes a new scene). This is the "matching
    # scopes are merged" path of §4.4 for scene trees.
    scene_remap: Dict[str, str] = {}
    thr = dst.config.scene_sim_threshold
    for skey, tree in src.trees.items():
        if tree.kind != "scene":
            continue
        sid = int(skey.split(":")[1])
        cent = src.scene_centroids[sid]
        if dst.scene_centroids.shape[0]:
            sims = dst.scene_centroids @ cent
            best = int(np.argmax(sims))
            if sims[best] >= thr:
                scene_remap[skey] = f"scene:{best}"
                c = dst.scene_counts[best]
                sc = src.scene_counts[sid]
                merged = (dst.scene_centroids[best] * c + cent * sc) / (c + sc)
                dst.scene_centroids[best] = merged / (np.linalg.norm(merged) + 1e-6)
                dst.scene_counts[best] += sc
                continue
        new_id = dst.scene_centroids.shape[0]
        scene_remap[skey] = f"scene:{new_id}"
        dst.scene_centroids = np.concatenate(
            [dst.scene_centroids, cent[None]], axis=0)
        dst.scene_counts.append(src.scene_counts[sid])

    for skey, src_tree in src.trees.items():
        if src_tree.root < 0:
            continue
        dkey = scene_remap.get(skey, skey)
        if dkey in dst.trees and dst.trees[dkey].root >= 0:
            # matched scope: bulk insert src leaves (time-ordered) — dirty paths
            t = dst.trees[dkey]
            for leaf in src_tree.leaves_in_order():
                p = src_tree.payload[leaf]
                if p is None:
                    continue
                if p >= 0:
                    item_kind, item_id = "fact", fact_id_map[p]
                    if not dst.fact_alive[item_id]:
                        continue
                else:
                    item_kind, item_id = "cell", cell_id_map[-p - 1]
                nl = t.insert_leaf(
                    item_id if item_kind == "fact" else -item_id - 1,
                    src_tree.start_ts[leaf], src_tree.emb[leaf], src_tree.text[leaf],
                )
                dst.placement.setdefault((item_kind, item_id), []).append((dkey, nl))
            dst.dirty_trees.add(dkey)
            stats["trees_merged"] += 1
        else:
            _copy_tree_into(dst, src_tree, dkey, fact_id_map, cell_id_map)
            stats["trees_copied"] += 1

    for sid, reg in src.session_registry.items():
        d = dst.session_registry.setdefault(sid, {"facts": [], "cells": []})
        # registry rows dedup like sources: targeted deletion counts on one
        # row per (session, fact)
        have = set(d["facts"])
        for f in reg["facts"]:
            if f in fact_id_map and fact_id_map[f] not in have:
                have.add(fact_id_map[f])
                d["facts"].append(fact_id_map[f])
        d["cells"].extend(cell_id_map[c] for c in reg["cells"] if c in cell_id_map)

    if flush:
        dst.flush()
    return stats


def rematerialize(forest: Forest, *, new_branching: int) -> Forest:
    """Policy/index migration (paper §4.4): rebuild trees from persistent
    state (facts + scope assignments) under a new tree configuration —
    NO re-extraction, NO session replay; fact embeddings are reused."""
    from repro.config import MemForestConfig
    import dataclasses

    new_cfg = dataclasses.replace(forest.config, branching_factor=new_branching)
    out = Forest(new_cfg, kernel_impl=forest.kernel_impl)
    # copy, never alias: facts/cells are mutable records (sources lists grow
    # on merge, cell_id is rewritten by add_cell) and fact_emb rows are
    # zeroed by kill_fact — sharing them let a delete_session or add_fact on
    # either forest corrupt the other. Embedding arrays inside the records
    # are write-never, so the record copy is shallow on those.
    out.facts = [dataclasses.replace(f, sources=list(f.sources))
                 for f in forest.facts]
    out.fact_alive = list(forest.fact_alive)
    out.fact_emb = forest.fact_emb.copy()
    out.cells = [copy.copy(c) for c in forest.cells]
    out.session_registry = {k: {kk: list(vv) for kk, vv in v.items()}
                            for k, v in forest.session_registry.items()}
    out.scene_centroids = forest.scene_centroids.copy()
    out.scene_counts = list(forest.scene_counts)
    out.applied_ops = set(forest.applied_ops)
    for skey, tree in forest.trees.items():
        for leaf in tree.leaves_in_order():
            p = tree.payload[leaf]
            if p is None or not tree.alive[leaf]:
                continue
            item_kind = "fact" if p >= 0 else "cell"
            item_id = p if p >= 0 else -p - 1
            out.insert_item(skey, tree.kind, item_kind, item_id,
                            tree.start_ts[leaf], tree.emb[leaf], tree.text[leaf])
    out.flush()
    return out


# ---------------------------------------------------------------------------
# compaction (maintenance-plane work item)
# ---------------------------------------------------------------------------
def tree_dead_fraction(tree: TreeArena) -> float:
    """Fraction of arena slots occupied by tombstoned nodes."""
    if tree._n == 0:
        return 0.0
    return 1.0 - (sum(tree.alive) / tree._n)


def compact_tree(forest: Forest, scope_key: str) -> Dict[str, int]:
    """Rebuild one tree's arena without its tombstoned nodes.

    ``delete_leaf`` tombstones (alive=False) rather than reclaiming slots,
    so churned trees accumulate dead arena rows that every flush gather and
    browse pack still pays for. Compaction re-inserts the live leaves (time
    order preserved) into a fresh arena, rewrites the affected placement
    rows, and leaves the new summaries to the normal lazy flush. Facts,
    cells, and the session registry are untouched, but the rewritten tree
    arena and placement rows ARE persistent state (forest_state_digest
    covers them) — on a durable store, compact through
    ``DurableMemForest.compact_tree`` so a crash replays it and recovers
    the same digest. The rebuild is deterministic, so replay is exact.
    """
    old = forest.trees[scope_key]
    live = [(old.payload[l], old.start_ts[l], old.emb[l].copy(), old.text[l])
            for l in old.leaves_in_order()
            if old.alive[l] and old.payload[l] is not None]
    reclaimed = old._n - len(live)

    t = TreeArena(old.tree_id, scope_key, old.kind, old.k, forest.config.embed_dim)
    forest.trees[scope_key] = t
    # drop this scope's stale placement rows, then re-add from the new leaves
    for payload, _ts, _emb, _text in live:
        pkey = ("fact", payload) if payload >= 0 else ("cell", -payload - 1)
        rows = forest.placement.get(pkey)
        if rows:
            forest.placement[pkey] = [r for r in rows if r[0] != scope_key]
    for payload, ts, emb, text in live:
        leaf = t.insert_leaf(payload, ts, emb, text)
        pkey = ("fact", payload) if payload >= 0 else ("cell", -payload - 1)
        forest.placement.setdefault(pkey, []).append((scope_key, leaf))
    forest.set_root_row(t)
    if live:
        forest.dirty_trees.add(scope_key)   # summaries regenerate lazily
    else:
        forest.dirty_trees.discard(scope_key)
    return {"nodes_before": old._n, "nodes_after": t._n,
            "slots_reclaimed": reclaimed, "leaves": len(live)}


def compaction_candidates(forest: Forest, *,
                          min_dead_fraction: float = 0.3) -> List[str]:
    """Scope keys whose trees have tombstone churn worth compacting."""
    return [k for k, t in forest.trees.items()
            if t._deleted_any and tree_dead_fraction(t) >= min_dead_fraction]
