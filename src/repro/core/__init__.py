from repro.core.memforest import MemForestSystem  # noqa: F401
from repro.core.forest import Forest  # noqa: F401
from repro.core.memtree import TreeArena  # noqa: F401
