"""MemForest system facade: the paper's full serve-and-update lifecycle.

    mf = MemForestSystem(MemForestConfig(), encoder)
    mf.ingest_session(session)   # write path: extract -> canonicalize ->
                                 # route -> materialize -> lazy flush
    mf.query(query)              # read path: forest recall -> tree browse ->
                                 # rerank -> answer
    mf.merge_from(other)         # migration merge (no session replay)
    mf.delete_session(sid)       # targeted deletion, dirty-path refresh
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.config import MemForestConfig
from repro.core import canonical, extraction, maintenance, routing
from repro.core.forest import Forest
from repro.core.ingest import IngestBatcher
from repro.core.retrieval import Retriever, answer_query
from repro.core.types import Query, QueryResult, Session, WriteStats
from repro.obs import Observability, get_obs


class MemForestSystem:
    name = "memforest"

    def __init__(self, config: Optional[MemForestConfig] = None, encoder=None,
                 kernel_impl: str = "reference", *, eager: bool = False,
                 parallel_extraction: bool = True,
                 obs: Optional[Observability] = None):
        from repro.core.encoder import HashingEncoder

        self.config = config or MemForestConfig()
        self.encoder = encoder or HashingEncoder(dim=self.config.embed_dim)
        self.obs = get_obs(obs)
        self.forest = Forest(self.config, kernel_impl=kernel_impl,
                             obs=self.obs)
        self.eager = eager                      # ablation: per-insert refresh
        if parallel_extraction:
            self.extractor = extraction.ParallelExtractor(
                self.encoder, chunk_turns=self.config.chunk_turns
            )
        else:
            self.extractor = extraction.SequentialExtractor(
                self.encoder, chunk_turns=self.config.chunk_turns
            )
        self.batcher = IngestBatcher(self.forest, self.extractor, self.config)
        self.retriever = Retriever(self.forest, self.encoder, self.config)
        self.write_stats = WriteStats()

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def ingest_session(self, session: Session) -> WriteStats:
        t0 = time.perf_counter()
        tok0 = self.encoder.stats.tokens
        call0 = self.encoder.stats.calls

        candidates, fact_embs, cells, ex_stats = self.extractor.extract_session(session)
        facts = canonical.canonicalize(
            candidates, fact_embs, self.forest,
            sim_threshold=self.config.canonical_sim_threshold,
        )
        max_depth = 0
        for cell in cells:
            self.forest.add_cell(cell)
            skey, _ = routing.materialize_cell(cell, self.forest)
            if self.eager:
                self.forest.eager_refresh_path(skey)
        for f in facts:
            scopes = routing.materialize_fact(f, self.forest)
            if self.eager:
                for skey, _leaf in scopes:
                    self.forest.eager_refresh_path(skey)
        if not self.eager and not self.config.read_triggered_refresh:
            flush = self.forest.flush()
            max_depth = flush["levels"]

        stats = WriteStats(
            wall_s=time.perf_counter() - t0,
            encoder_tokens=self.encoder.stats.tokens - tok0,
            encoder_calls=self.encoder.stats.calls - call0,
            llm_dependency_depth=ex_stats.llm_dependency_depth + max_depth,
            summary_refreshes=self.forest.summary_refreshes,
            facts_written=len(facts),
        )
        self.write_stats.add(stats)
        return stats

    def ingest_batch(self, sessions: List[Session], *,
                     defer_flush: bool = False) -> List[WriteStats]:
        """Batched write path: N sessions, ONE encoder forward, ONE lazy
        flush whose tree_refresh batches span every session's dirty trees
        (cross-tenant parallelism). State-equivalent to calling
        ingest_session on each session in order.

        ``defer_flush=True`` skips the flush and leaves the dirty trees for
        the maintenance plane (core/maintenance_plane.py) or the next
        reader — the serve engine uses this so ingest drains never block on
        refresh kernels.

        Eager mode has no batch form (it refreshes per insert by
        definition), so it falls back to the sequential loop."""
        if self.eager:
            return [self.ingest_session(s) for s in sessions]
        stats = self.batcher.ingest(
            sessions,
            flush=not (defer_flush or self.config.read_triggered_refresh))
        for s in stats:
            self.write_stats.add(s)
        return stats

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def query(self, q: Query, mode: Optional[str] = None,
              final_topk: Optional[int] = None) -> QueryResult:
        t0 = time.perf_counter()
        if self.forest.dirty_trees:
            # read-triggered refresh: first reader pays the deferred flush
            self.forest.flush()
        facts, evidence, rstats = self.retriever.retrieve(
            q.text, mode=mode, final_topk=final_topk
        )
        t1 = time.perf_counter()
        ans = answer_query(q, facts)
        return QueryResult(
            answer=ans,
            evidence=evidence,
            retrieval_s=rstats["retrieval_s"],
            answer_s=time.perf_counter() - t1,
            encoder_calls=rstats["encoder_calls"],
        )

    def query_batch(self, qs: List[Query], mode: Optional[str] = None,
                    final_topk: Optional[int] = None) -> List[QueryResult]:
        """Batched serving path: one encoder forward, one fused topk_sim per
        device-resident index across all queries (kernel Q-dimension), one
        planner forward, and a level-synchronous browse that scores each
        depth level of every (query, tree) lane in a single kernel launch.
        Result-identical to calling query() per element."""
        if self.forest.dirty_trees:
            self.forest.flush()
        results = self.retriever.retrieve_batch(
            [q.text for q in qs], mode=mode, final_topk=final_topk)
        out = []
        for q, (facts, evidence, rstats) in zip(qs, results):
            t1 = time.perf_counter()
            ans = answer_query(q, facts)
            out.append(QueryResult(
                answer=ans, evidence=evidence,
                retrieval_s=rstats["retrieval_s"] / max(len(qs), 1),
                answer_s=time.perf_counter() - t1,
                encoder_calls=rstats["encoder_calls"],
            ))
        return out

    # ------------------------------------------------------------------
    # multi-device serve
    # ------------------------------------------------------------------
    def set_mesh(self, mesh, axis: str = "data") -> None:
        """Shard the serve path across ``mesh``'s data axis: the fact index
        (rows round-robin, roots replicated), the browse-lane frontier, and
        the flush's cross-tree refresh batches. ``None`` restores the
        single-device fast path. Results are identical either way —
        placement is the only thing that changes (kernels/shard_ops)."""
        self.forest.set_mesh(mesh, axis)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def merge_from(self, other: "MemForestSystem", *,
                   idempotency_key: Optional[str] = None) -> Dict[str, int]:
        # in-memory facade: DurableMemForest overrides this with the
        # journaled op; callers holding a durable handle never reach here
        # memlint: ignore[journaled-mutation]
        return maintenance.migrate_merge(self.forest, other.forest,
                                         idempotency_key=idempotency_key)

    def delete_session(self, session_id: str) -> Dict[str, int]:
        # in-memory facade: journaled counterpart lives on DurableMemForest
        # memlint: ignore[journaled-mutation]
        return maintenance.delete_session(self.forest, session_id)

    def scale_stats(self) -> Dict[str, int]:
        return self.forest.scale_stats()

    def device_bytes(self) -> int:
        """Bytes currently pinned by the device-resident index caches."""
        return self.forest.device_bytes()

    def detach_device(self) -> int:
        """Release the device index caches (residency demotion); the next
        query transparently re-uploads. Returns bytes freed."""
        return self.forest.detach_device()

    def state_digest(self) -> str:
        """Content hash of persistent state (persistence.forest_state_digest)
        — the state-identity relation recovery tests compare against."""
        from repro.core import persistence
        return persistence.forest_state_digest(self.forest)

    # ------------------------------------------------------------------
    # durability
    # ------------------------------------------------------------------
    def save(self, path: str, *, with_derived: bool = True) -> str:
        from repro.core import persistence
        return persistence.save_forest(self.forest, path, with_derived=with_derived)

    @classmethod
    def load(cls, path: str, config=None, encoder=None, *,
             rematerialize_derived: bool = False) -> "MemForestSystem":
        from repro.core import persistence
        forest = persistence.load_forest(
            path, config, rematerialize_derived=rematerialize_derived)
        sys_ = cls(forest.config, encoder)
        forest.obs = sys_.obs           # rebuilt forest reports to our registry
        sys_.forest = forest
        sys_.retriever.forest = forest
        sys_.batcher.forest = forest
        return sys_
