"""Batched multi-session ingestion engine (cross-tenant write batching).

``MemForestSystem.ingest_session`` processes one session at a time, so the
batched encoder forward and the level-parallel ``tree_refresh`` kernel only
ever see one session's worth of work. Real deployments ingest many tenants'
sessions concurrently; the :class:`IngestBatcher` turns that concurrency
into batch dimensions:

  1. **extract**   — every session is chunked, and the union of all chunk
     texts + candidate texts across the whole batch is embedded in ONE
     encoder forward (``ParallelExtractor.extract_sessions``);
  2. **canonicalize** — one single pass over all sessions' candidates with
     the existing-key map built once and a vectorized (gemm) near-duplicate
     similarity gate (``canonical.canonicalize_batch``);
  3. **route/materialize** — leaves land in per-scope trees in session
     arrival order (scene clustering is order-dependent state, so this
     stays a loop — it is host-side numpy and cheap);
  4. **flush**     — ONE lazy ``Forest.flush()`` whose per-level
     ``tree_refresh`` batches span every dirty tree across every session in
     the batch: the paper's same-level/cross-tree parallelism becomes
     cross-*tenant* parallelism.

The resulting forest state is equivalent to sequentially ingesting the same
sessions in the same order (same facts, same tree structure, same query
answers) — tests/test_ingest_batch.py asserts this — while encoder forwards
and refresh kernel launches stop scaling with the number of sessions.

Multi-device serve: when the Forest carries a mesh (``Forest.set_mesh``),
the flush's per-level ``tree_refresh`` batches are additionally padded to a
shard multiple and sharded over the mesh's data axis inside
``Forest._refresh_batch`` — nothing changes here, and the refreshed
embeddings are bitwise identical to the mesh=None flush (per-parent math is
row-local; see kernels/shard_ops.py).
"""
from __future__ import annotations

import time
from typing import List, Sequence

from repro.core import canonical, routing
from repro.core.types import Session, WriteStats


class IngestBatcher:
    """Batches whole-session writes against one Forest.

    Stateless between calls apart from counters; safe to reuse. The batcher
    requires an extractor with ``extract_sessions`` (ParallelExtractor and
    SequentialExtractor both provide it — the latter degrades to per-chunk
    encoder calls but still shares canonicalization and the single flush).
    """

    def __init__(self, forest, extractor, config):
        self.forest = forest
        self.extractor = extractor
        self.config = config
        self.batches = 0
        self.sessions_ingested = 0

    def ingest(self, sessions: Sequence[Session], *,
               flush: bool = True) -> List[WriteStats]:
        """Ingest a batch of sessions; returns per-session WriteStats.

        ``flush=False`` leaves the forest dirty (read-triggered refresh
        deployments let the first reader pay the deferred flush)."""
        if not sessions:
            return []
        encoder = self.extractor.encoder
        t0 = time.perf_counter()
        tok0 = encoder.stats.tokens
        call0 = encoder.stats.calls
        refresh0 = self.forest.summary_refreshes

        extractions, ex_stats = self.extractor.extract_sessions(sessions)
        per_session_facts = canonical.canonicalize_batch(
            [(e.candidates, e.fact_embs) for e in extractions],
            self.forest,
            sim_threshold=self.config.canonical_sim_threshold,
        )
        for ext, facts in zip(extractions, per_session_facts):
            for cell in ext.cells:
                self.forest.add_cell(cell)
                routing.materialize_cell(cell, self.forest)
            for f in facts:
                routing.materialize_fact(f, self.forest)

        levels = 0
        if flush:
            levels = self.forest.flush()["levels"]

        self.batches += 1
        self.sessions_ingested += len(sessions)

        # batch-level costs (wall clock, encoder forwards, flush depth) are
        # amortized: attributed to the batch's first stats object, zero on
        # the rest — summing per-session stats reproduces batch totals
        wall = time.perf_counter() - t0
        out: List[WriteStats] = []
        for i, facts in enumerate(per_session_facts):
            out.append(WriteStats(
                wall_s=wall if i == 0 else 0.0,
                encoder_tokens=(encoder.stats.tokens - tok0) if i == 0 else 0,
                encoder_calls=(encoder.stats.calls - call0) if i == 0 else 0,
                llm_dependency_depth=ex_stats.llm_dependency_depth + levels,
                summary_refreshes=(self.forest.summary_refreshes - refresh0)
                if i == 0 else 0,
                facts_written=len(facts),
            ))
        return out
