"""Core data model: sessions, dialogue cells, canonical facts, scopes.

The *canonical fact* is the paper's stable write unit (§3.1): one temporally
anchored piece of memory with retrieval-ready text, source references,
entity mention, topical signal, and a temporal anchor inherited from the
source session.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class Turn:
    role: str                 # "user" | "assistant"
    text: str
    ts: float                 # unix-style timestamp
    turn_id: int = 0


@dataclass
class Session:
    session_id: str
    turns: List[Turn]
    ts: float = 0.0

    def __post_init__(self):
        if not self.ts and self.turns:
            self.ts = self.turns[0].ts


@dataclass
class DialogueCell:
    """A chunk of raw dialogue — session-tree leaf payload (high-fidelity
    fallback channel)."""
    cell_id: int
    session_id: str
    chunk_idx: int
    text: str
    ts: float
    emb: Optional[np.ndarray] = None


@dataclass
class CanonicalFact:
    fact_id: int
    text: str                 # retrieval-ready statement
    subject: str              # normalized entity label
    attribute: str            # topical signal
    value: str
    ts: float                 # temporal anchor
    prev_value: Optional[str] = None     # transition evidence ("moved FROM x")
    sources: List[Tuple[str, int]] = field(default_factory=list)  # (session, chunk)
    emb: Optional[np.ndarray] = None

    def key(self) -> Tuple[str, str, str]:
        return (self.subject, self.attribute, self.value)


@dataclass
class RawCandidate:
    """Pre-canonicalization extraction output (may be fragmented/duplicated)."""
    text: str
    subject: str
    attribute: str
    value: str
    ts: float
    prev_value: Optional[str]
    source: Tuple[str, int]


@dataclass
class Query:
    text: str
    qtype: str                # current | historical | transition_time | multi_session | single_session
    subject: str
    attribute: str
    anchor_value: Optional[str] = None   # for "before moving to X"
    gold: str = ""
    session_scope: Optional[str] = None


@dataclass
class QueryResult:
    answer: str
    evidence: List[str]
    retrieval_s: float = 0.0
    answer_s: float = 0.0
    encoder_calls: int = 0


@dataclass
class WriteStats:
    wall_s: float = 0.0
    encoder_tokens: int = 0
    encoder_calls: int = 0        # number of model invocations (batched = 1)
    llm_dependency_depth: int = 0  # longest dependent chain of model calls
    summary_refreshes: int = 0     # distinct node refreshes
    facts_written: int = 0

    def add(self, other: "WriteStats") -> None:
        self.wall_s += other.wall_s
        self.encoder_tokens += other.encoder_tokens
        self.encoder_calls += other.encoder_calls
        self.llm_dependency_depth = max(self.llm_dependency_depth, other.llm_dependency_depth)
        self.summary_refreshes += other.summary_refreshes
        self.facts_written += other.facts_written
