"""Write path stage 3: scope routing (paper §4.2, Eq. 6).

Routing needs NO LLM calls after extraction: session scope comes from the
source session, entity scope from the normalized subject label, scene scope
from nearest-centroid online clustering over topical embeddings (lightweight
cluster state: centroid + member counts, kept in the Forest).

Entity and scene trees take canonical facts as leaves; session trees take
dialogue cells (high-fidelity fallback channel).
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.forest import Forest
from repro.core.types import CanonicalFact, DialogueCell


def route_fact(fact: CanonicalFact, forest: Forest) -> List[Tuple[str, str]]:
    """Returns [(scope_key, kind)] for a canonical fact."""
    scopes = [(f"entity:{fact.subject.lower()}", "entity")]
    scene_id = forest.route_scene(fact.emb)
    scopes.append((f"scene:{scene_id}", "scene"))
    return scopes


def materialize_fact(fact: CanonicalFact, forest: Forest) -> List[Tuple[str, int]]:
    leaves = []
    for scope_key, kind in route_fact(fact, forest):
        leaf = forest.insert_item(
            scope_key, kind, "fact", fact.fact_id, fact.ts, fact.emb, fact.text
        )
        leaves.append((scope_key, leaf))
    return leaves


def materialize_cell(cell: DialogueCell, forest: Forest) -> Tuple[str, int]:
    scope_key = f"session:{cell.session_id}"
    leaf = forest.insert_item(
        scope_key, "session", "cell", cell.cell_id, cell.ts, cell.emb,
        cell.text[:200],
    )
    return scope_key, leaf
