"""Durable persistence for the memory substrate (paper §3.1: "persistent
state is the source of truth ... derived artifacts can be regenerated").

Snapshot format (msgpack + tagged compression — zstd when available, stdlib
zlib fallback — single file):
  * persistent state: canonical facts, dialogue cells, scope assignments,
    tree STRUCTURE, placement maps, session registry, scene cluster state,
    applied idempotency keys (exactly-once bookkeeping for the write-ahead
    journal, core/journal.py);
  * derived artifacts (node embeddings, summaries, root rows) are stored
    too by default — restore is then instant — but `restore(..., \
    rematerialize_derived=True)` drops them and regenerates everything from
    persistent state via the normal lazy flush, exercising the paper's
    migration path ("regenerate selected derived artifacts ... without
    replaying the session stream").

The doc-level API (`forest_to_doc` / `forest_from_doc` / `read_doc`) is
shared by three consumers: file snapshots here, the migrate-merge payloads
the write-ahead journal must replay byte-identically, and the structural
`forest_state_digest` the recovery tests compare crash-replayed state
against.
"""
from __future__ import annotations

import hashlib
import os
from typing import Any, Dict, Optional

import msgpack
import numpy as np

from repro import compression
from repro.config import MemForestConfig
from repro.runtime.checkpoint import fsync_dir
from repro.core.forest import Forest
from repro.core.memtree import TreeArena
from repro.core.types import CanonicalFact, DialogueCell

# v2 adds "applied_ops" (journal exactly-once keys), "extra" (journal
# watermark), and — in with_derived docs — the dirty-flush bookkeeping
# ("dirty_trees" + per-tree "dirty" node sets). A snapshot taken under
# deferred flush bakes in stale internal summaries; without the dirty marks
# a restore would report has_derived state as clean and read-triggered
# refresh would never repair it. v1 docs load with all of these empty.
#
# v3 (residency): a snapshot written by a tenant demotion
# (DurableMemForest.demote) carries extra["residency"] = {"demoted": True,
# "journal_seq": ...} — the demotion record. Demotion itself is
# checkpoint-class, not a journal op: the journal rotates at the demoting
# checkpoint, so a demoted tenant's journal tail is empty and rehydration
# is plain snapshot + (empty) tail recovery. "extra" stays excluded from
# forest_state_digest, so residency transitions never change state
# identity. The always-resident digest sidecar (root summaries + normalized
# root embeddings, core/residency.py) lives NEXT TO the snapshot as a
# separate DIGEST file — it is derived state, rebuilt at each demotion, and
# deliberately outside the snapshot so demotion never rewrites history.
# v1/v2 docs load unchanged (no residency record).
FORMAT_VERSION = 3


def _fact_rec(f: CanonicalFact) -> Dict[str, Any]:
    return {
        "id": f.fact_id, "text": f.text, "subject": f.subject,
        "attribute": f.attribute, "value": f.value, "ts": f.ts,
        "prev": f.prev_value, "sources": [list(s) for s in f.sources],
        "emb": f.emb.astype(np.float32).tobytes() if f.emb is not None else b"",
    }


def _tree_rec(t: TreeArena, with_derived: bool) -> Dict[str, Any]:
    return {
        "tree_id": t.tree_id, "scope_key": t.scope_key, "kind": t.kind,
        "k": t.k, "n": t._n, "root": t.root,
        "parent": list(t.parent), "children": [list(c) for c in t.children],
        "level": list(t.level), "start_ts": list(t.start_ts),
        "end_ts": list(t.end_ts), "payload": list(t.payload),
        "alive": list(t.alive), "deleted_any": t._deleted_any,
        "text": list(t.text) if with_derived else [""] * t._n,
        "emb": t.emb[:t._n].astype(np.float32).tobytes() if with_derived else b"",
        # dirty bookkeeping rides only with the derived state it qualifies;
        # the with_derived=False doc feeds forest_state_digest, which must
        # stay independent of flush progress
        "dirty": sorted(t.dirty) if with_derived else [],
    }


def forest_to_doc(forest: Forest, *, with_derived: bool = True,
                  extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Serialize a forest to a plain msgpack-able document."""
    cfg = forest.config
    return {
        "version": FORMAT_VERSION,
        "config": {
            "chunk_turns": cfg.chunk_turns, "branching_factor": cfg.branching_factor,
            "embed_dim": cfg.embed_dim, "tree_families": list(cfg.tree_families),
        },
        "facts": [_fact_rec(f) for f in forest.facts],
        "fact_alive": list(forest.fact_alive),
        "cells": [
            {"id": c.cell_id, "session": c.session_id, "chunk": c.chunk_idx,
             "text": c.text, "ts": c.ts,
             "emb": c.emb.astype(np.float32).tobytes() if c.emb is not None else b""}
            for c in forest.cells
        ],
        "trees": [_tree_rec(forest.trees[k], with_derived)
                  for k in forest._tree_order],
        "tree_order": list(forest._tree_order),
        "placement": [
            [k[0], k[1], [list(v) for v in vs]]
            for k, vs in forest.placement.items()
        ],
        "session_registry": {
            k: {"facts": v["facts"], "cells": v["cells"]}
            for k, v in forest.session_registry.items()
        },
        "scene_centroids": forest.scene_centroids.astype(np.float32).tobytes(),
        "scene_counts": list(forest.scene_counts),
        "applied_ops": sorted(forest.applied_ops),
        "dirty_trees": sorted(forest.dirty_trees) if with_derived else [],
        "extra": extra or {},
        "with_derived": with_derived,
    }


def doc_to_bytes(doc: Dict[str, Any]) -> bytes:
    return compression.compress(msgpack.packb(doc, use_bin_type=True))


def bytes_to_doc(payload: bytes) -> Dict[str, Any]:
    return msgpack.unpackb(compression.decompress(payload), raw=False)


def save_forest(forest: Forest, path: str, *, with_derived: bool = True,
                extra: Optional[Dict[str, Any]] = None) -> str:
    payload = doc_to_bytes(forest_to_doc(forest, with_derived=with_derived,
                                         extra=extra))
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(os.path.abspath(path)))
    return path


def read_doc(path: str) -> Dict[str, Any]:
    with open(path, "rb") as f:
        return bytes_to_doc(f.read())


def forest_from_doc(doc: Dict[str, Any], config: Optional[MemForestConfig] = None,
                    *, rematerialize_derived: bool = False,
                    kernel_impl: str = "reference") -> Forest:
    assert doc["version"] in (1, 2, FORMAT_VERSION), doc["version"]
    cfg = config or MemForestConfig(
        chunk_turns=doc["config"]["chunk_turns"],
        branching_factor=doc["config"]["branching_factor"],
        embed_dim=doc["config"]["embed_dim"],
        tree_families=tuple(doc["config"]["tree_families"]),
    )
    dim = cfg.embed_dim
    forest = Forest(cfg, kernel_impl=kernel_impl)

    for rec in doc["facts"]:
        emb = np.frombuffer(rec["emb"], np.float32).copy() if rec["emb"] else None
        f = CanonicalFact(
            fact_id=rec["id"], text=rec["text"], subject=rec["subject"],
            attribute=rec["attribute"], value=rec["value"], ts=rec["ts"],
            prev_value=rec["prev"],
            sources=[tuple(s) for s in rec["sources"]], emb=emb,
        )
        forest.facts.append(f)
    forest.fact_alive = list(doc["fact_alive"])
    cap = max(64, 1 << max(len(forest.facts) - 1, 0).bit_length())
    forest.fact_emb = np.zeros((cap, dim), np.float32)
    for f in forest.facts:
        # dead facts keep their record (provenance) but their index row must
        # stay zeroed — restoring it would resurrect deleted facts in
        # topk_sim. The device cache starts at None, so the first
        # fact_index_device() uploads exactly this host state.
        if f.emb is not None and forest.fact_alive[f.fact_id]:
            forest.fact_emb[f.fact_id] = f.emb

    for rec in doc["cells"]:
        emb = np.frombuffer(rec["emb"], np.float32).copy() if rec["emb"] else None
        forest.cells.append(DialogueCell(
            cell_id=rec["id"], session_id=rec["session"], chunk_idx=rec["chunk"],
            text=rec["text"], ts=rec["ts"], emb=emb,
        ))

    has_derived = doc["with_derived"] and not rematerialize_derived
    for rec in doc["trees"]:
        t = TreeArena(rec["tree_id"], rec["scope_key"], rec["kind"],
                      rec["k"], dim)
        n = rec["n"]
        t._n = n
        t.parent = list(rec["parent"])
        t.children = [list(c) for c in rec["children"]]
        t.level = list(rec["level"])
        t.start_ts = list(rec["start_ts"])
        t.end_ts = list(rec["end_ts"])
        t.payload = list(rec["payload"])
        t.alive = list(rec["alive"])
        t._deleted_any = rec["deleted_any"]
        t.text = list(rec["text"])
        t.emb = np.zeros((max(n, 8), dim), np.float32)
        if rec["emb"]:
            t.emb[:n] = np.frombuffer(rec["emb"], np.float32).reshape(n, dim)
        t.root = rec["root"]
        if has_derived:
            # snapshots taken under deferred flush carry their dirty paths;
            # re-marking them keeps read-triggered refresh (and the
            # maintenance plane) able to repair the stale summaries
            t.dirty = set(rec.get("dirty", []))
        forest.trees[rec["scope_key"]] = t
    forest._tree_order = list(doc["tree_order"])
    cap_t = max(8, 1 << max(len(forest._tree_order) - 1, 0).bit_length())
    forest._root_matrix = np.zeros((cap_t, dim), np.float32)

    for kind, item_id, vs in doc["placement"]:
        forest.placement[(kind, item_id)] = [(v[0], v[1]) for v in vs]
    forest.session_registry = {
        k: {"facts": list(v["facts"]), "cells": list(v["cells"])}
        for k, v in doc["session_registry"].items()
    }
    sc = np.frombuffer(doc["scene_centroids"], np.float32)
    forest.scene_centroids = sc.reshape(-1, dim).copy() if sc.size else \
        np.zeros((0, dim), np.float32)
    forest.scene_counts = list(doc["scene_counts"])
    forest.applied_ops = set(doc.get("applied_ops", []))

    if has_derived:
        forest.dirty_trees = set(doc.get("dirty_trees", []))
        for t in forest.trees.values():
            forest._root_matrix[t.tree_id] = t.root_emb()
    else:
        # regenerate ALL derived artifacts from persistent state: leaf embs
        # come from facts/cells; internal summaries from the lazy flush
        for t in forest.trees.values():
            for nid in range(t._n):
                if not t.alive[nid]:
                    continue
                if t.level[nid] == 0 and t.payload[nid] is not None:
                    p = t.payload[nid]
                    if p >= 0:
                        src = forest.facts[p]
                        t.emb[nid] = src.emb
                        t.text[nid] = src.text
                    else:
                        cell = forest.cells[-p - 1]
                        t.emb[nid] = cell.emb
                        t.text[nid] = cell.text[:200]
                    t._mark_dirty_path(nid)
            forest.dirty_trees.add(t.scope_key)
        forest.flush()
    return forest


def load_forest(path: str, config: Optional[MemForestConfig] = None,
                *, rematerialize_derived: bool = False,
                kernel_impl: str = "reference") -> Forest:
    return forest_from_doc(read_doc(path), config,
                           rematerialize_derived=rematerialize_derived,
                           kernel_impl=kernel_impl)


def forest_state_digest(forest: Forest) -> str:
    """Content hash of the forest's PERSISTENT state (facts, cells, tree
    structure, placement, registry, scenes, applied keys) — derived
    artifacts (summaries, node embeddings, root rows, flush bookkeeping) are
    excluded, so two forests that differ only in how far their lazy flush
    has progressed digest equal. This is the state-identity relation the
    crash-recovery tests assert: snapshot + journal replay must reproduce
    the uninterrupted run's digest bit-for-bit."""
    doc = forest_to_doc(forest, with_derived=False)
    doc.pop("extra", None)
    return hashlib.sha256(
        msgpack.packb(doc, use_bin_type=True)).hexdigest()
