from repro.core.baselines.mem0_like import Mem0Like
from repro.core.baselines.memoryos_like import MemoryOSLike
from repro.core.baselines.evermem_like import EverMemLike
from repro.core.baselines.lightmem_like import LightMemLike
from repro.core.baselines.mempalace_like import MemPalaceLike

ALL_BASELINES = {
    "mem0": Mem0Like,
    "memoryos": MemoryOSLike,
    "evermem": EverMemLike,
    "lightmem": LightMemLike,
    "mempalace": MemPalaceLike,
}
