"""Shared backend interface + cost accounting for baseline memory systems.

Each baseline reproduces the WRITE CRITICAL PATH CLASS of its reference
system (paper Table 1 / Appendix B). "LLM work" is an encoder forward with
the same dependency structure as the original: calls on a dependency chain
use `sequential=True` (one forward per call — serialization is real
wall-clock here), independent calls are batched.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.retrieval import answer_query
from repro.core.types import CanonicalFact, Query, QueryResult, Session, WriteStats
from repro.data import templates as T
from repro.kernels import ops


class MemoryBackend:
    name = "base"

    def __init__(self, encoder):
        self.encoder = encoder
        self.write_stats = WriteStats()

    def ingest_session(self, session: Session) -> WriteStats:
        raise NotImplementedError

    def query(self, q: Query, final_topk: int = 10) -> QueryResult:
        raise NotImplementedError

    def _begin(self):
        return time.perf_counter(), self.encoder.stats.tokens, self.encoder.stats.calls

    def _end(self, t0, tok0, call0, depth: int, facts: int) -> WriteStats:
        s = WriteStats(
            wall_s=time.perf_counter() - t0,
            encoder_tokens=self.encoder.stats.tokens - tok0,
            encoder_calls=self.encoder.stats.calls - call0,
            llm_dependency_depth=depth,
            facts_written=facts,
        )
        self.write_stats.add(s)
        return s


class FactStore:
    """Flat embedding-indexed fact store shared by several baselines."""

    def __init__(self, dim: int):
        self.dim = dim
        self.facts: List[CanonicalFact] = []
        self.emb = np.zeros((0, dim), np.float32)
        self.alive: List[bool] = []

    def add(self, fact: CanonicalFact, emb: np.ndarray) -> int:
        fact.fact_id = len(self.facts)
        self.facts.append(fact)
        self.alive.append(True)
        if fact.fact_id >= self.emb.shape[0]:
            grow = max(64, self.emb.shape[0])
            self.emb = np.concatenate([self.emb, np.zeros((grow, self.dim), np.float32)])
        self.emb[fact.fact_id] = emb
        fact.emb = emb
        return fact.fact_id

    def topk(self, q_emb: np.ndarray, k: int) -> List[CanonicalFact]:
        n = len(self.facts)
        if n == 0:
            return []
        # capacity-padded matrix + runtime valid count: the jit-compile set
        # stays O(log N) as the store grows
        vals, idx = ops.topk_sim(
            jnp.asarray(q_emb[None]), jnp.asarray(self.emb), min(k, n),
            num_valid=n,
        )
        out = []
        for i in np.asarray(idx[0]):
            if i >= 0 and self.alive[int(i)]:
                out.append(self.facts[int(i)])
        return out

    @property
    def size(self) -> int:
        return sum(self.alive)


def turns_to_candidates(session: Session) -> List[Tuple[int, str, float, List]]:
    """(turn_idx, text, ts, parsed candidates) for user turns."""
    out = []
    for i, t in enumerate(session.turns):
        if t.role != "user":
            continue
        cands = T.parse_statement(t.text, (session.session_id, i))
        out.append((i, t.text, t.ts, cands))
    return out
