"""Mem0-class baseline (paper §2.3.2, Appendix B.2): mutable memory records
with embedding retrieval and per-record LLM update adjudication.

Write path per new record: Search(r, K) -> LLMUpdate(r, retrieved) ->
Mutate(S, action). The update call is STATE-DEPENDENT (decisions change with
order), so records are processed sequentially — O(M) dependency depth.
Update semantics overwrite same-(subject, attribute) records (the paper's
historical-evidence loss failure mode).
"""
from __future__ import annotations

from typing import List

from repro.core.baselines.base import FactStore, MemoryBackend, turns_to_candidates
from repro.core.retrieval import answer_query
from repro.core.types import CanonicalFact, Query, QueryResult, Session, WriteStats

RETRIEVE_K = 8


class Mem0Like(MemoryBackend):
    name = "mem0"

    def __init__(self, encoder, *, infer: bool = True):
        super().__init__(encoder)
        self.store = FactStore(encoder.dim)
        self.infer = infer

    def ingest_session(self, session: Session) -> WriteStats:
        t0, tok0, call0 = self._begin()
        depth = 0
        nfacts = 0
        for _idx, text, ts, cands in turns_to_candidates(session):
            for c in cands:
                # Search: embed the new record (independent) ...
                emb = self.encoder.encode([c.text])[0]
                cand_facts = self.store.topk(emb, RETRIEVE_K)
                # ... LLMUpdate: sequential, reads current memory state
                ctx = c.text + " || " + " | ".join(f.text for f in cand_facts)
                self.encoder.encode([ctx], sequential=True)
                depth += 1
                # Mutate: update-in-place if same key exists (loses history)
                action = "add"
                for f in cand_facts:
                    if f.subject == c.subject and f.attribute == c.attribute:
                        action = "update"
                        f.text = c.text
                        f.value = c.value
                        f.ts = c.ts
                        f.prev_value = c.prev_value
                        self.store.emb[f.fact_id] = emb
                        f.emb = emb
                        break
                if action == "add":
                    self.store.add(CanonicalFact(
                        fact_id=-1, text=c.text, subject=c.subject,
                        attribute=c.attribute, value=c.value, ts=c.ts,
                        prev_value=c.prev_value, sources=[c.source], emb=None,
                    ), emb)
                    nfacts += 1
        return self._end(t0, tok0, call0, depth, nfacts)

    def query(self, q: Query, final_topk: int = 10) -> QueryResult:
        import time
        t0 = time.perf_counter()
        q_emb = self.encoder.encode([q.text])[0]
        facts = self.store.topk(q_emb, final_topk)
        t1 = time.perf_counter()
        ans = answer_query(q, facts)
        return QueryResult(answer=ans, evidence=[f.text for f in facts],
                           retrieval_s=t1 - t0, answer_s=time.perf_counter() - t1)
