"""EverMemOS-class baseline (Appendix B.4): streaming MemCell formation.

Boundary detection is an ORDERED stream step (b_i depends on H_{i-1}) — one
sequential encoder call per turn. Post-boundary extraction + embedding is
parallel (batched). Per-record O(1) vs memory size but O(M) ordered depth
within a session: accurate but slow writes (the paper's Table 2)."""
from __future__ import annotations

from typing import List

from repro.core.baselines.base import FactStore, MemoryBackend, turns_to_candidates
from repro.core.retrieval import answer_query
from repro.core.types import CanonicalFact, Query, QueryResult, Session, WriteStats

CELL_TARGET = 4  # turns per MemCell (boundary heuristic)


class EverMemLike(MemoryBackend):
    name = "evermem"

    def __init__(self, encoder):
        super().__init__(encoder)
        self.store = FactStore(encoder.dim)
        self.cells: List[str] = []
        self.cell_store = FactStore(encoder.dim)

    def ingest_session(self, session: Session) -> WriteStats:
        t0, tok0, call0 = self._begin()
        depth = 0
        nfacts = 0
        turns = turns_to_candidates(session)
        # 1) ordered boundary pass (sequential, one call per turn)
        cells: List[List] = [[]]
        for i, (idx, text, ts, cands) in enumerate(turns):
            self.encoder.encode([text], sequential=True)  # Boundary(H_{i-1}, r_i)
            depth += 1
            cells[-1].append((text, ts, cands))
            if len(cells[-1]) >= CELL_TARGET:
                cells.append([])
        cells = [c for c in cells if c]
        # 2) per-cell extraction + consolidation (parallel: one batch)
        cell_texts = [" ".join(t for t, _, _ in c) for c in cells]
        if cell_texts:
            cell_embs = self.encoder.encode(cell_texts)
            for ct, ce in zip(cell_texts, cell_embs):
                self.cells.append(ct)
                self.cell_store.add(CanonicalFact(
                    fact_id=-1, text=ct[:200], subject="", attribute="cell",
                    value="", ts=0.0, sources=[], emb=None), ce)
        fact_texts = []
        fact_meta = []
        for c in cells:
            for _t, _ts, cands in c:
                for cand in cands:
                    fact_texts.append(cand.text)
                    fact_meta.append(cand)
        if fact_texts:
            embs = self.encoder.encode(fact_texts)
            depth += 1
            for cand, e in zip(fact_meta, embs):
                self.store.add(CanonicalFact(
                    fact_id=-1, text=cand.text, subject=cand.subject,
                    attribute=cand.attribute, value=cand.value, ts=cand.ts,
                    prev_value=cand.prev_value, sources=[cand.source], emb=None,
                ), e)
                nfacts += 1
        return self._end(t0, tok0, call0, depth, nfacts)

    def query(self, q: Query, final_topk: int = 10) -> QueryResult:
        import time
        t0 = time.perf_counter()
        # agentic pipeline: retrieve facts, check sufficiency, reformulate once
        q_emb = self.encoder.encode([q.text])[0]
        facts = self.store.topk(q_emb, final_topk)
        ans = answer_query(q, facts)
        if not ans and q.anchor_value:
            q2 = self.encoder.encode([q.text + " " + q.anchor_value])[0]
            facts = self.store.topk(q2, final_topk)
        t1 = time.perf_counter()
        ans = answer_query(q, facts)
        return QueryResult(answer=ans, evidence=[f.text for f in facts],
                           retrieval_s=t1 - t0, answer_s=time.perf_counter() - t1)
