"""MemPalace-class baseline (Appendix B.6): append-oriented raw history.

O(1) write path, fully parallelizable, NO write-time semantic maintenance —
abstraction deferred to query time. Strong fidelity on local lookups, weak on
temporal composition (no structured temporal state)."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.baselines.base import FactStore, MemoryBackend
from repro.core.extraction import chunk_session
from repro.core.retrieval import answer_query
from repro.core.types import CanonicalFact, Query, QueryResult, Session, WriteStats
from repro.data import templates as T


class MemPalaceLike(MemoryBackend):
    name = "mempalace"

    def __init__(self, encoder, chunk_turns: int = 2):
        super().__init__(encoder)
        self.chunks: List[Tuple[str, str, int]] = []   # (text, session, idx)
        self.store = FactStore(encoder.dim)
        self.b = chunk_turns

    def ingest_session(self, session: Session) -> WriteStats:
        t0, tok0, call0 = self._begin()
        chunks = chunk_session(session, self.b)
        texts = [c[1] for c in chunks]
        embs = self.encoder.encode(texts)              # one batch, depth 1
        for (idx, text, ts), e in zip(chunks, embs):
            self.chunks.append((text, session.session_id, idx))
            self.store.add(CanonicalFact(
                fact_id=-1, text=text[:300], subject="", attribute="chunk",
                value="", ts=ts, sources=[(session.session_id, idx)], emb=None,
            ), e)
        return self._end(t0, tok0, call0, 1, 0)

    def query(self, q: Query, final_topk: int = 10) -> QueryResult:
        import time
        t0 = time.perf_counter()
        q_emb = self.encoder.encode([q.text])[0]
        raw = self.store.topk(q_emb, final_topk)
        # query-time extraction from raw chunks
        facts: List[CanonicalFact] = []
        for r in raw:
            src = r.sources[0] if r.sources else ("", 0)
            for cand in T.parse_statement(r.text, src):
                facts.append(CanonicalFact(
                    fact_id=-1, text=cand.text, subject=cand.subject,
                    attribute=cand.attribute, value=cand.value, ts=cand.ts,
                    prev_value=cand.prev_value, sources=[cand.source], emb=None))
        t1 = time.perf_counter()
        ans = answer_query(q, facts)
        return QueryResult(answer=ans, evidence=[r.text[:120] for r in raw],
                           retrieval_s=t1 - t0, answer_s=time.perf_counter() - t1)
