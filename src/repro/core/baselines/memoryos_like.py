"""MemoryOS-class baseline (Appendix B.3): short/mid/long-term tiers with
ordered promotion and hot profile rewrites.

Write path: AppendQueue -> PageUpdate -> ProfileUpdate. The profile is a
mutable text state; each triggered update REREADS AND REWRITES the whole
profile (O(N) touched state) and the chain is ordered. The profile keeps
only latest values (compression discards transitions), which is the paper's
accuracy failure on historical/temporal queries.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Tuple

from repro.core.baselines.base import FactStore, MemoryBackend, turns_to_candidates
from repro.core.retrieval import answer_query
from repro.core.types import CanonicalFact, Query, QueryResult, Session, WriteStats

QUEUE_CAP = 8
PAGE_SIZE = 4


class MemoryOSLike(MemoryBackend):
    name = "memoryos"

    def __init__(self, encoder):
        super().__init__(encoder)
        self.queue: Deque[Tuple[str, float]] = deque(maxlen=QUEUE_CAP)
        self.pages: List[str] = []                       # mid-term
        self.profile: Dict[Tuple[str, str], CanonicalFact] = {}  # long-term latest-state
        self.profile_text = ""
        self.recent_store = FactStore(encoder.dim)       # queue+pages index

    def ingest_session(self, session: Session) -> WriteStats:
        t0, tok0, call0 = self._begin()
        depth = 0
        nfacts = 0
        pending: List[str] = []
        for _idx, text, ts, cands in turns_to_candidates(session):
            self.queue.append((text, ts))
            pending.append(text)
            if len(pending) >= PAGE_SIZE:
                # PageUpdate: ordered summarization of the page
                page = " ".join(pending)
                self.encoder.encode([page], sequential=True)
                depth += 1
                self.pages.append(page)
                pending = []
            for c in cands:
                # ProfileUpdate: reread + rewrite the WHOLE profile text
                self.profile[(c.subject, c.attribute)] = CanonicalFact(
                    fact_id=-1, text=c.text, subject=c.subject,
                    attribute=c.attribute, value=c.value, ts=c.ts,
                    prev_value=c.prev_value, sources=[c.source], emb=None,
                )
                self.profile_text = " ".join(
                    f.text for f in self.profile.values()
                )
                self.encoder.encode([self.profile_text], sequential=True)  # O(N)
                depth += 1
                nfacts += 1
                emb = self.encoder.encode([c.text])[0]
                self.recent_store.add(CanonicalFact(
                    fact_id=-1, text=c.text, subject=c.subject,
                    attribute=c.attribute, value=c.value, ts=c.ts,
                    prev_value=c.prev_value, sources=[c.source], emb=None,
                ), emb)
        if pending:
            self.encoder.encode([" ".join(pending)], sequential=True)
            depth += 1
            self.pages.append(" ".join(pending))
        return self._end(t0, tok0, call0, depth, nfacts)

    def query(self, q: Query, final_topk: int = 10) -> QueryResult:
        import time
        t0 = time.perf_counter()
        # profile answers current-state; recent store adds top-k recency
        facts = [f for (s, a), f in self.profile.items()
                 if s.lower() == q.subject.lower() and a == q.attribute]
        q_emb = self.encoder.encode([q.text])[0]
        facts += self.recent_store.topk(q_emb, max(final_topk - len(facts), 0))
        t1 = time.perf_counter()
        ans = answer_query(q, facts)
        return QueryResult(answer=ans, evidence=[f.text for f in facts],
                           retrieval_s=t1 - t0, answer_s=time.perf_counter() - t1)
