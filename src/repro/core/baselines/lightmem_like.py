"""LightMem-class baseline (Appendix B.5): buffer accumulation + triggered
extraction + global consolidation.

Buffer updates are ordered; when consolidation triggers, candidates are
compared against a GLOBAL memory snapshot — O(N) touched state per trigger.
Compression (short summaries) loses detail on assistant-side/temporal
evidence (the paper's Table 4 pattern)."""
from __future__ import annotations

from typing import List

from repro.core.baselines.base import FactStore, MemoryBackend, turns_to_candidates
from repro.core.retrieval import answer_query
from repro.core.types import CanonicalFact, Query, QueryResult, Session, WriteStats

BUFFER_TRIGGER = 6


class LightMemLike(MemoryBackend):
    name = "lightmem"

    def __init__(self, encoder):
        super().__init__(encoder)
        self.store = FactStore(encoder.dim)
        self.buffer: List = []
        self.consolidations = 0

    def _consolidate(self) -> int:
        """Global consolidation: compare buffered candidates against the whole
        store (O(N) encoder work over the snapshot)."""
        depth = 0
        texts = [c.text for c in self.buffer]
        if not texts:
            return 0
        embs = self.encoder.encode(texts)            # batched extraction
        depth += 1
        # global pass: reread existing memory (compressed snapshot)
        snapshot = " ".join(
            f.text for f, a in zip(self.store.facts, self.store.alive) if a
        )[:4000]
        if snapshot:
            self.encoder.encode([snapshot], sequential=True)
            depth += 1
        for c, e in zip(self.buffer, embs):
            dup = False
            for f, a in zip(self.store.facts, self.store.alive):
                if a and f.subject == c.subject and f.attribute == c.attribute \
                        and f.value == c.value:
                    dup = True
                    break
            if not dup:
                self.store.add(CanonicalFact(
                    fact_id=-1, text=c.text, subject=c.subject,
                    attribute=c.attribute, value=c.value, ts=c.ts,
                    prev_value=c.prev_value, sources=[c.source], emb=None), e)
        self.buffer = []
        self.consolidations += 1
        return depth

    def ingest_session(self, session: Session) -> WriteStats:
        t0, tok0, call0 = self._begin()
        depth = 0
        n0 = self.store.size
        for _idx, text, ts, cands in turns_to_candidates(session):
            self.buffer.extend(cands)                # ordered buffer update
            if len(self.buffer) >= BUFFER_TRIGGER:
                depth += self._consolidate()
        depth += self._consolidate()
        return self._end(t0, tok0, call0, depth, self.store.size - n0)

    def query(self, q: Query, final_topk: int = 10) -> QueryResult:
        import time
        t0 = time.perf_counter()
        q_emb = self.encoder.encode([q.text])[0]
        facts = self.store.topk(q_emb, final_topk)
        t1 = time.perf_counter()
        ans = answer_query(q, facts)
        return QueryResult(answer=ans, evidence=[f.text for f in facts],
                           retrieval_s=t1 - t0, answer_s=time.perf_counter() - t1)
