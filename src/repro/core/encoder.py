"""Embedding encoders for the memory substrate.

HashingEncoder — deterministic, CPU-fast, jitted: token/bigram hashing into a
fixed random projection. Used by benchmarks so write-path timings measure the
*system* (batching, dependency structure), with a realistic per-call forward
cost model.

ModelEncoder — a zoo LM as the builder backbone: tokenize, run the trunk,
mean-pool. Used by examples/serve_memforest.py with a small dense model —
the same code path a production deployment would use with Qwen3 (the paper's
builder).

Both count calls and tokens so benchmarks can report Table-2-style cost.
"""
from __future__ import annotations

import functools
import re
import zlib
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _stable_hash(s: str) -> int:
    """Process-stable string hash (python's hash() is salted per process)."""
    return zlib.crc32(s.encode())

_TOKEN_RE = re.compile(r"[a-z0-9]+")
_HASH_BUCKETS = 8192
# high-frequency glue words contribute almost nothing to a trained embedding
# model's similarity; the hashing stand-in drops them outright.
_STOP = frozenset(
    "a an the of in on at to as is was are were did does do now then it this "
    "that i you he she we they my your his her what where when which who".split()
)


def _tokenize(text: str) -> List[int]:
    toks = [t for t in _TOKEN_RE.findall(text.lower()) if t not in _STOP]
    ids = []
    for i, t in enumerate(toks):
        ids.append(_stable_hash(t) % _HASH_BUCKETS)
        if i + 1 < len(toks):
            ids.append(_stable_hash(t + "_" + toks[i + 1]) % _HASH_BUCKETS)
    return ids or [0]


@functools.partial(jax.jit, static_argnames=("dim",))
def _project(counts: jax.Array, table: jax.Array, dim: int) -> jax.Array:
    """counts: (B, BUCKETS) sparse-ish count vectors -> (B, dim) normalized."""
    h = jnp.tanh(counts @ table)
    n = jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6
    return h / n


class EncoderStats:
    def __init__(self):
        self.calls = 0          # model invocations (a batch = 1 call)
        self.sequential_calls = 0  # calls that were on a dependency chain
        self.tokens = 0
        self.texts = 0

    def reset(self):
        self.__init__()


class HashingEncoder:
    """Deterministic hashing encoder with LLM-like cost accounting."""

    def __init__(self, dim: int = 256, seed: int = 0, max_batch: int = 1024):
        self.dim = dim
        rng = np.random.default_rng(seed)
        self._table = jnp.asarray(
            rng.normal(size=(_HASH_BUCKETS, dim)) / np.sqrt(dim), jnp.float32
        )
        self.stats = EncoderStats()
        self.max_batch = max_batch

    def encode(self, texts: Sequence[str], *, sequential: bool = False) -> np.ndarray:
        """Batched encode. `sequential=True` marks calls that sit on a write
        dependency chain (baselines' state-dependent updates) — they are
        executed one-by-one to reproduce the serialization honestly."""
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        if sequential:
            outs = [self._encode_batch([t]) for t in texts]
            self.stats.sequential_calls += len(texts)
            return np.concatenate(outs, axis=0)
        outs = []
        for i in range(0, len(texts), self.max_batch):
            outs.append(self._encode_batch(texts[i:i + self.max_batch]))
        return np.concatenate(outs, axis=0)

    def _encode_batch(self, texts: Sequence[str]) -> np.ndarray:
        n = len(texts)
        # pad batch to a power-of-two bucket: bounded jit-compile set
        cap = 1
        while cap < n:
            cap *= 2
        counts = np.zeros((cap, _HASH_BUCKETS), np.float32)
        ntok = 0
        for i, t in enumerate(texts):
            ids = _tokenize(t)
            ntok += len(ids)
            np.add.at(counts[i], ids, 1.0)
        self.stats.calls += 1
        self.stats.tokens += ntok
        self.stats.texts += n
        out = _project(jnp.asarray(counts), self._table, self.dim)
        return np.asarray(out)[:n]

    def encode_one(self, text: str) -> np.ndarray:
        return self.encode([text])[0]


class ModelEncoder:
    """Zoo-LM-backed encoder: trunk forward + masked mean-pool."""

    def __init__(self, cfg, params, tokenizer, max_len: int = 128):
        from repro.models import get_model  # lazy: avoids cycle
        from repro.models import transformer as T
        from repro.models import layers as L

        self.cfg = cfg
        self.params = params
        self.tok = tokenizer
        self.max_len = max_len
        self.dim = cfg.d_model
        self.stats = EncoderStats()

        def pooled(params, tokens, mask):
            x = params["embed"][tokens]
            h, _ = T.trunk(params, cfg, x, jnp.arange(tokens.shape[1])[None, :])
            m = mask[..., None].astype(h.dtype)
            s = jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
            n = jnp.linalg.norm(s.astype(jnp.float32), axis=-1, keepdims=True) + 1e-6
            return (s.astype(jnp.float32) / n)

        self._pooled = jax.jit(pooled)

    def encode(self, texts: Sequence[str], *, sequential: bool = False) -> np.ndarray:
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        if sequential:
            self.stats.sequential_calls += len(texts)
            return np.concatenate([self._fwd([t]) for t in texts], axis=0)
        return self._fwd(list(texts))

    def _fwd(self, texts: List[str]) -> np.ndarray:
        ids = [self.tok.encode(t)[: self.max_len] for t in texts]
        L = max(len(i) for i in ids)
        toks = np.zeros((len(ids), L), np.int32)
        mask = np.zeros((len(ids), L), np.float32)
        for i, seq in enumerate(ids):
            toks[i, : len(seq)] = seq
            mask[i, : len(seq)] = 1.0
        self.stats.calls += 1
        self.stats.tokens += int(mask.sum())
        self.stats.texts += len(texts)
        return np.asarray(self._pooled(self.params, jnp.asarray(toks), jnp.asarray(mask)))

    def encode_one(self, text: str) -> np.ndarray:
        return self.encode([text])[0]
