"""Embedding encoders for the memory substrate.

HashingEncoder — deterministic, CPU-fast, jitted: token/bigram hashing into a
fixed random projection. Used by benchmarks so write-path timings measure the
*system* (batching, dependency structure), with a realistic per-call forward
cost model.

ModelEncoder — a zoo LM as the builder backbone: tokenize, run the trunk,
mean-pool. Used by examples/serve_memforest.py with a small dense model —
the same code path a production deployment would use with Qwen3 (the paper's
builder).

Both count calls and tokens so benchmarks can report Table-2-style cost.
"""
from __future__ import annotations

import functools
import re
import zlib
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _stable_hash(s: str) -> int:
    """Process-stable string hash (python's hash() is salted per process)."""
    return zlib.crc32(s.encode())

_TOKEN_RE = re.compile(r"[a-z0-9]+")
_HASH_BUCKETS = 8192
# high-frequency glue words contribute almost nothing to a trained embedding
# model's similarity; the hashing stand-in drops them outright.
_STOP = frozenset(
    "a an the of in on at to as is was are were did does do now then it this "
    "that i you he she we they my your his her what where when which who".split()
)


# vocabulary-level id caches (what a trained tokenizer's vocab table is):
# unigram/bigram hashing is pure, and natural-language token vocabularies
# are small, so memoizing ids takes the per-token crc32+encode off the
# write path's host floor. Size-capped: arbitrary alphanumeric tokens (ids,
# hashes) would otherwise grow the dicts without bound in a long-lived
# serving process — on overflow we just stop inserting (misses stay cheap).
_VOCAB_CACHE_MAX = 1 << 16
_UNI_IDS: dict = {}
_BI_IDS: dict = {}


def _tokenize(text: str) -> List[int]:
    toks = [t for t in _TOKEN_RE.findall(text.lower()) if t not in _STOP]
    ids: List[int] = []
    append = ids.append
    prev = None
    for t in toks:
        if prev is not None:
            b = _BI_IDS.get((prev, t))
            if b is None:
                b = _stable_hash(prev + "_" + t) % _HASH_BUCKETS
                if len(_BI_IDS) < _VOCAB_CACHE_MAX:
                    _BI_IDS[(prev, t)] = b
            append(b)
        u = _UNI_IDS.get(t)
        if u is None:
            u = _stable_hash(t) % _HASH_BUCKETS
            if len(_UNI_IDS) < _VOCAB_CACHE_MAX:
                _UNI_IDS[t] = u
        append(u)
        prev = t
    return ids or [0]


@functools.partial(jax.jit, static_argnames=("num_rows",))
def _project(flat_ids: jax.Array, seg: jax.Array, table: jax.Array,
             num_rows: int) -> jax.Array:
    """flat_ids: (N,) bucket ids across all texts, seg: (N,) row index per
    token (sorted; padding tokens carry seg == num_rows) -> (num_rows, dim).

    Computes tanh(counts @ table) in token-gather/segment-sum form: the
    per-row sum of table rows is the same bucket-count contraction without
    materializing either the (B, BUCKETS) dense count matrix or a (B, L)
    padded id matrix — host->device traffic and gather work scale with the
    REAL token count, not with batch x longest-text padding, which keeps
    large mixed-length cross-session ingest batches bandwidth-cheap."""
    contrib = jax.ops.segment_sum(
        table[flat_ids], seg, num_segments=num_rows + 1,
        indices_are_sorted=True)[:num_rows]
    h = jnp.tanh(contrib)
    n = jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6
    return h / n


class EncoderStats:
    def __init__(self):
        self.calls = 0          # model invocations (a batch = 1 call)
        self.sequential_calls = 0  # calls that were on a dependency chain
        self.tokens = 0
        self.texts = 0

    def reset(self):
        self.__init__()


class HashingEncoder:
    """Deterministic hashing encoder with LLM-like cost accounting."""

    def __init__(self, dim: int = 256, seed: int = 0, max_batch: int = 1024):
        self.dim = dim
        rng = np.random.default_rng(seed)
        self._table = jnp.asarray(
            rng.normal(size=(_HASH_BUCKETS, dim)) / np.sqrt(dim), jnp.float32
        )
        self.stats = EncoderStats()
        self.max_batch = max_batch

    def encode(self, texts: Sequence[str], *, sequential: bool = False) -> np.ndarray:
        """Batched encode. `sequential=True` marks calls that sit on a write
        dependency chain (baselines' state-dependent updates) — they are
        executed one-by-one to reproduce the serialization honestly."""
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        if sequential:
            outs = [self._encode_batch([t]) for t in texts]
            self.stats.sequential_calls += len(texts)
            return np.concatenate(outs, axis=0)
        outs = []
        for i in range(0, len(texts), self.max_batch):
            outs.append(self._encode_batch(texts[i:i + self.max_batch]))
        return np.concatenate(outs, axis=0)

    def _encode_batch(self, texts: Sequence[str]) -> np.ndarray:
        n = len(texts)
        # pad batch rows AND the flat token stream to power-of-two buckets:
        # bounded jit-compile set across the system's lifetime
        cap = 1
        while cap < n:
            cap *= 2
        id_lists = [_tokenize(t) for t in texts]
        ntok = sum(len(ids) for ids in id_lists)
        cap_tok = 16
        while cap_tok < ntok:
            cap_tok *= 2
        flat = np.zeros(cap_tok, np.int32)
        seg = np.full(cap_tok, cap, np.int32)   # padding -> scratch segment
        pos = 0
        for i, ids in enumerate(id_lists):
            flat[pos:pos + len(ids)] = ids
            seg[pos:pos + len(ids)] = i
            pos += len(ids)
        self.stats.calls += 1
        self.stats.tokens += ntok
        self.stats.texts += n
        out = _project(jnp.asarray(flat), jnp.asarray(seg), self._table, cap)
        return np.asarray(out)[:n]

    def encode_one(self, text: str) -> np.ndarray:
        return self.encode([text])[0]


class ModelEncoder:
    """Zoo-LM-backed encoder: trunk forward + masked mean-pool."""

    def __init__(self, cfg, params, tokenizer, max_len: int = 128):
        from repro.models import get_model  # lazy: avoids cycle
        from repro.models import transformer as T
        from repro.models import layers as L

        self.cfg = cfg
        self.params = params
        self.tok = tokenizer
        self.max_len = max_len
        self.dim = cfg.d_model
        self.stats = EncoderStats()

        def pooled(params, tokens, mask):
            x = params["embed"][tokens]
            h, _ = T.trunk(params, cfg, x, jnp.arange(tokens.shape[1])[None, :])
            m = mask[..., None].astype(h.dtype)
            s = jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
            n = jnp.linalg.norm(s.astype(jnp.float32), axis=-1, keepdims=True) + 1e-6
            return (s.astype(jnp.float32) / n)

        self._pooled = jax.jit(pooled)

    def encode(self, texts: Sequence[str], *, sequential: bool = False) -> np.ndarray:
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        if sequential:
            self.stats.sequential_calls += len(texts)
            return np.concatenate([self._fwd([t]) for t in texts], axis=0)
        return self._fwd(list(texts))

    def _fwd(self, texts: List[str]) -> np.ndarray:
        ids = [self.tok.encode(t)[: self.max_len] for t in texts]
        L = max(len(i) for i in ids)
        toks = np.zeros((len(ids), L), np.int32)
        mask = np.zeros((len(ids), L), np.float32)
        for i, seq in enumerate(ids):
            toks[i, : len(seq)] = seq
            mask[i, : len(seq)] = 1.0
        self.stats.calls += 1
        self.stats.tokens += int(mask.sum())
        self.stats.texts += len(texts)
        return np.asarray(self._pooled(self.params, jnp.asarray(toks), jnp.asarray(mask)))

    def encode_one(self, text: str) -> np.ndarray:
        return self.encode([text])[0]
