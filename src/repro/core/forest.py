"""Forest: the shared memory substrate (paper §3.1) + batched lazy refresh
(Algorithm 1).

Persistent state (source of truth): canonical facts, dialogue cells, scope
assignments, MemTree structure, placement maps, session registry.
Derived artifacts: interval summaries, node embeddings, root-index rows,
fact-index rows — regenerated selectively from dirty paths.

`flush()` is Algorithm 1 lines 9-22: dirty nodes are collected by level
across ALL dirty trees, and each level is refreshed in ONE batched
`tree_refresh` kernel call — the paper's same-level/cross-tree parallelism
mapped onto the TPU batch dimension. The dependent depth is the max dirty
level (= deepest affected tree path), not the number of touched paths.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.config import MemForestConfig
from repro.core.memtree import TreeArena
from repro.core.types import CanonicalFact, DialogueCell
from repro.kernels import ops, shard_ops
from repro.obs import Observability, get_obs


class Forest:
    def __init__(self, config: MemForestConfig, kernel_impl: str = "reference",
                 obs: Optional[Observability] = None):
        self.config = config
        self.kernel_impl = kernel_impl
        self.obs = get_obs(obs)
        self.trees: Dict[str, TreeArena] = {}
        self._tree_order: List[str] = []          # tree_id -> scope_key
        self.facts: List[CanonicalFact] = []
        self.fact_emb = np.zeros((0, config.embed_dim), np.float32)
        self.fact_alive: List[bool] = []
        self.cells: List[DialogueCell] = []
        # placement: ("fact"|"cell", item_id) -> [(scope_key, node_id)]
        self.placement: Dict[Tuple[str, int], List[Tuple[str, int]]] = {}
        self.session_registry: Dict[str, Dict[str, List[int]]] = {}
        # exactly-once bookkeeping: idempotency keys of applied lifecycle
        # ops (journaled ingest/delete/merge). Persisted in snapshots, so a
        # snapshot + journal-tail replay never double-applies an op.
        self.applied_ops: Set[str] = set()
        # scene clustering state
        self.scene_centroids = np.zeros((0, config.embed_dim), np.float32)
        self.scene_counts: List[int] = []
        self.dirty_trees: Set[str] = set()
        # derived: root index
        self._root_matrix = np.zeros((0, config.embed_dim), np.float32)
        # device-resident L2-normalized index caches (read path): the fact
        # and root matrices live on device between queries, invalidated
        # incrementally — appends sync [synced, n), in-place edits land in a
        # dirty-row set, capacity growth grows the device buffer in place
        # (geometric, no re-upload). topk_sim then runs with normalize=False:
        # no per-query host->device transfer and no O(N*D) re-normalization.
        self._fact_dev = None
        self._fact_dev_rows = 0
        self._fact_dev_dirty: Set[int] = set()
        self._root_dev = None
        self._root_dev_rows = 0
        self._root_dev_dirty: Set[int] = set()
        # multi-device serve: when a mesh is attached (set_mesh), the fact
        # index cache is row-sharded round-robin over the mesh's data axis
        # and read through kernels/shard_ops; the root index is replicated.
        # mesh=None is the single-device fast path (byte-identical to the
        # pre-mesh code).
        self.mesh = None
        self.mesh_axis = "data"
        # counters (benchmarks read these)
        self.summary_refreshes = 0
        self.flush_levels = 0
        self.flush_calls = 0
        self.index_uploads = 0          # full device (re-)uploads
        self.index_row_updates = 0      # incremental scatter updates
        self.index_grows = 0            # device-side capacity grows
        self.index_releases = 0         # device-cache frees (demotion)

    # ------------------------------------------------------------------
    # persistent-state writes
    # ------------------------------------------------------------------
    def get_tree(self, scope_key: str, kind: str) -> TreeArena:
        t = self.trees.get(scope_key)
        if t is None:
            t = TreeArena(len(self._tree_order), scope_key, kind,
                          self.config.branching_factor, self.config.embed_dim)
            self.trees[scope_key] = t
            self._tree_order.append(scope_key)
            if len(self._tree_order) > self._root_matrix.shape[0]:
                grow = max(8, self._root_matrix.shape[0])
                self._root_matrix = np.concatenate(
                    [self._root_matrix, np.zeros((grow, self.config.embed_dim), np.float32)]
                )
                # capacity growth: _sync_device grows the device buffer in
                # place (no full re-upload)
        return t

    def add_fact(self, fact: CanonicalFact) -> int:
        fact.fact_id = len(self.facts)
        self.facts.append(fact)
        self.fact_alive.append(True)
        if fact.fact_id >= self.fact_emb.shape[0]:
            grow = max(64, self.fact_emb.shape[0])
            self.fact_emb = np.concatenate(
                [self.fact_emb, np.zeros((grow, self.config.embed_dim), np.float32)]
            )
            # capacity growth: device buffer grows in place at next sync
        self.fact_emb[fact.fact_id] = fact.emb
        sid = fact.sources[0][0] if fact.sources else ""
        self.session_registry.setdefault(sid, {"facts": [], "cells": []})["facts"].append(fact.fact_id)
        return fact.fact_id

    def kill_fact(self, fact_id: int) -> None:
        """Mark a fact dead and inert its index row (host + device)."""
        self.fact_alive[fact_id] = False
        self.fact_emb[fact_id] = 0.0
        self._fact_dev_dirty.add(fact_id)

    def add_cell(self, cell: DialogueCell) -> int:
        cell.cell_id = len(self.cells)
        self.cells.append(cell)
        self.session_registry.setdefault(cell.session_id, {"facts": [], "cells": []})["cells"].append(cell.cell_id)
        return cell.cell_id

    def insert_item(self, scope_key: str, kind: str, item_kind: str,
                    item_id: int, ts: float, emb: np.ndarray, text: str) -> int:
        tree = self.get_tree(scope_key, kind)
        leaf = tree.insert_leaf(item_id if item_kind == "fact" else -item_id - 1, ts, emb, text)
        self.placement.setdefault((item_kind, item_id), []).append((scope_key, leaf))
        self.dirty_trees.add(scope_key)
        return leaf

    # ------------------------------------------------------------------
    # lazy refresh (Algorithm 1) — level-parallel, batched across trees
    # ------------------------------------------------------------------
    def flush(self, *, level_parallel: Optional[bool] = None,
              only: Optional[Set[str]] = None) -> Dict[str, int]:
        """Refresh all dirty derived artifacts. Returns counters for this
        flush: {"refreshes": distinct dirty nodes, "levels": dependent depth,
        "kernel_calls": batched refresh invocations}.

        ``only`` restricts the flush to a subset of the dirty trees — the
        maintenance plane uses this to drain refresh work in bounded chunks
        between serve steps. Because dirty paths never cross trees, flushing
        the dirty set in any chunking yields the same final derived state as
        one full flush."""
        if level_parallel is None:
            level_parallel = self.config.level_parallel
        self.flush_calls += 1
        targets = set(self.dirty_trees) if only is None else \
            self.dirty_trees & set(only)
        with self.obs.span("forest.flush", trees=len(targets)) as sp:
            out = self._flush(level_parallel, targets)
            sp.set(refreshes=out["refreshes"], levels=out["levels"],
                   kernel_calls=out["kernel_calls"])
        return out

    def _flush(self, level_parallel: bool, targets: Set[str]) -> Dict[str, int]:
        K = self.config.branching_factor
        dim = self.config.embed_dim
        per_tree = {tid: self.trees[tid].dirty_by_level() for tid in targets}
        max_level = 0
        refreshes = 0
        kernel_calls = 0
        for levels in per_tree.values():
            for lam in levels:
                max_level = max(max_level, lam)

        for lam in range(1, max_level + 1):
            batch: List[Tuple[TreeArena, int]] = []
            for tid, levels in per_tree.items():
                tree = self.trees[tid]
                for n in levels.get(lam, []):
                    batch.append((tree, n))
            if not batch:
                continue
            if level_parallel:
                kernel_calls += self._refresh_batch(batch, K, dim)
            else:
                # ablation: one kernel call per node (paper Fig. 6c baseline)
                for item in batch:
                    kernel_calls += self._refresh_batch([item], K, dim)
            refreshes += len(batch)

        # leaves count as refreshed artifacts only for bookkeeping
        for tid, levels in per_tree.items():
            tree = self.trees[tid]
            refreshes += len(levels.get(0, []))
            tree.dirty.clear()

        # root-index rows for dirty trees (derived artifact)
        for tid in targets:
            tree = self.trees[tid]
            self._root_matrix[tree.tree_id] = tree.root_emb()
            self._root_dev_dirty.add(tree.tree_id)
        self.dirty_trees -= targets

        self.summary_refreshes += refreshes
        self.flush_levels += max_level
        return {"refreshes": refreshes, "levels": max_level, "kernel_calls": kernel_calls}

    def _refresh_batch(self, batch: List[Tuple[TreeArena, int]], K: int, dim: int) -> int:
        P = len(batch)
        # pad the parent dim to a power-of-two bucket: the jit-compile set for
        # the refresh kernel stays O(log P_max) across the system's lifetime.
        # With a mesh attached the bucket additionally pads to a shard
        # multiple so the cross-tree batch splits evenly over the data axis.
        cap = 1
        while cap < P:
            cap *= 2
        if self.mesh is not None:
            cap = shard_ops.pad_rows(cap, self._shards())
        with self.obs.span("forest.tree_refresh", parents=P, padded=cap):
            child_emb = np.zeros((cap, K, dim), np.float32)
            mask = np.zeros((cap, K), np.float32)
            for i, (tree, n) in enumerate(batch):
                kids = tree.children[n][:K]
                for j, c in enumerate(kids):
                    child_emb[i, j] = tree.emb[c]
                    mask[i, j] = 1.0
            if self.mesh is not None:
                out = np.asarray(shard_ops.sharded_tree_refresh(
                    child_emb, mask, mesh=self.mesh, axis=self.mesh_axis,
                    impl=self.kernel_impl))
            else:
                out = np.asarray(ops.tree_refresh(
                    jnp.asarray(child_emb), jnp.asarray(mask),
                    impl=self.kernel_impl))
            for i, (tree, n) in enumerate(batch):
                tree.emb[n] = out[i]
                tree.refresh_text(n)
        return 1

    def eager_refresh_path(self, scope_key: str) -> int:
        """Ablation baseline (paper Fig. 6a): refresh the dirty path of one
        tree immediately, one node per call, bottom-up. Returns #calls."""
        tree = self.trees[scope_key]
        levels = tree.dirty_by_level()
        calls = 0
        for lam in sorted(l for l in levels if l >= 1):
            for n in levels[lam]:
                calls += self._refresh_batch([(tree, n)], self.config.branching_factor,
                                             self.config.embed_dim)
        tree.dirty.clear()
        self._root_matrix[tree.tree_id] = tree.root_emb()
        self._root_dev_dirty.add(tree.tree_id)
        self.dirty_trees.discard(scope_key)
        self.summary_refreshes += calls
        return calls

    # ------------------------------------------------------------------
    # derived-index views (retrieval reads these)
    # ------------------------------------------------------------------
    def root_index(self) -> Tuple[np.ndarray, int, List[str]]:
        """(capacity-padded matrix, valid count, tree order)."""
        return self._root_matrix, len(self._tree_order), list(self._tree_order)

    def fact_index(self) -> Tuple[np.ndarray, int]:
        """(capacity-padded matrix, valid count). Dead facts' rows are zeroed
        on deletion; callers filter by fact_alive."""
        return self.fact_emb, len(self.facts)

    def set_root_row(self, tree: TreeArena) -> None:
        """Write a tree's root-index row (host + device invalidation) — the
        one sanctioned way to edit ``_root_matrix`` outside flush()."""
        self._root_matrix[tree.tree_id] = tree.root_emb()
        self._root_dev_dirty.add(tree.tree_id)

    # ------------------------------------------------------------------
    # multi-device serve (mesh-sharded index + flush batches)
    # ------------------------------------------------------------------
    def set_mesh(self, mesh, axis: str = "data") -> None:
        """Attach a serve mesh: the fact index shards round-robin over the
        mesh's ``axis`` (kernels/shard_ops layout), the root index
        replicates, and flush/browse batches run shard-mapped. ``None`` (or
        a mesh whose data axis is width 1) restores the single-device fast
        path. Resets the device caches so the next sync uploads with the new
        layout; persistent state is untouched, so results are identical
        across any mesh change (tests/test_sharded_serve.py)."""
        if mesh is not None and shard_ops.mesh_shards(mesh, axis) <= 1:
            mesh = None
        self.mesh = mesh
        self.mesh_axis = axis
        self._fact_dev = None
        self._fact_dev_rows = 0
        self._fact_dev_dirty.clear()
        self._root_dev = None
        self._root_dev_rows = 0
        self._root_dev_dirty.clear()

    def _shards(self) -> int:
        return shard_ops.mesh_shards(self.mesh, self.mesh_axis)

    # ------------------------------------------------------------------
    # residency: device-cache detach (tenant demotion) + footprint
    # ------------------------------------------------------------------
    def device_bytes(self) -> int:
        """Bytes currently held by the device-resident index caches (the
        capacity-padded arenas, f32). 0 when detached / never materialized."""
        total = 0
        for arr in (self._fact_dev, self._root_dev):
            if arr is not None:
                total += int(np.prod(arr.shape)) * 4
        return total

    def estimated_device_bytes(self) -> int:
        """Host-side footprint estimate (index rows x dim x 4B) — what the
        caches WOULD occupy once materialized. The residency budget planner
        uses this so a hot-but-not-yet-queried tenant still counts against
        the device budget."""
        return 4 * self.config.embed_dim * (
            int(self.fact_emb.shape[0]) + int(self._root_matrix.shape[0]))

    def detach_device(self) -> int:
        """Tenant demotion: eagerly free both device index caches
        (``ops.release_rows``; ``index_releases`` counts freed arenas,
        mirroring ``index_grows``) and return the bytes released.

        Reattachment is transparent — the next ``fact_index_device()`` /
        ``root_index_device()`` call re-uploads from host state exactly like
        a freshly loaded snapshot, so only the rehydrated tenant's rows ever
        transfer (other tenants' caches are untouched). Persistent and host
        derived state are unaffected; results are identical across a
        detach/reattach round-trip."""
        freed = self.device_bytes()
        for arr in (self._fact_dev, self._root_dev):
            if arr is not None:
                ops.release_rows(arr)
                self.index_releases += 1
        self._fact_dev = None
        self._fact_dev_rows = 0
        self._fact_dev_dirty.clear()
        self._root_dev = None
        self._root_dev_rows = 0
        self._root_dev_dirty.clear()
        return freed

    # ------------------------------------------------------------------
    # device-resident normalized index views (retrieval hot path)
    # ------------------------------------------------------------------
    def _sync_device(self, host: np.ndarray, n: int, cached, synced_rows: int,
                     dirty: Set[int], *, sharded: bool = False):
        """Bring one device index cache up to date with its host matrix.
        Returns (device array, new synced row count).

        Capacity growth is geometric and device-side: when the host matrix
        outgrows the cached buffer, the buffer gains zero rows IN PLACE
        (ops.grow_rows / shard_ops.grow_sharded) and only new/dirty rows are
        scattered — steady ingest never re-uploads or re-normalizes the
        whole index. Full uploads happen only on first use, dtype/dim
        change, shrink (snapshot restore), or mesh change.

        ``sharded=True`` (the fact index) uses the round-robin sharded
        layout when a mesh is attached; the root index stays replicated."""
        mesh = self.mesh if sharded else None
        S = shard_ops.mesh_shards(mesh, self.mesh_axis)
        cap = shard_ops.pad_rows(host.shape[0], S)
        if cached is not None and (cached.shape[1] != host.shape[1]
                                   or cached.shape[0] > cap):
            cached = None
        if cached is None:
            self.index_uploads += 1
            dirty.clear()
            if mesh is not None:
                return shard_ops.upload_sharded(host, cap, mesh,
                                                self.mesh_axis), n
            if self.mesh is not None:
                return shard_ops.upload_replicated(host, self.mesh), n
            return ops.normalize_rows(jnp.asarray(host)), n
        if cached.shape[0] < cap:
            self.index_grows += 1
            if mesh is not None:
                cached = shard_ops.grow_sharded(cached, cap, mesh,
                                                self.mesh_axis)
            else:
                cached = ops.grow_rows(cached, cap - cached.shape[0])
        rows = sorted(set(r for r in dirty if r < n)
                      | set(range(synced_rows, n)))
        dirty.clear()
        if not rows:
            return cached, n
        # bucket the update size: the jit-compile set for the scatter stays
        # O(log U_max); padding entries carry a drop sentinel (out-of-bounds
        # index single-device, -1 in the sharded layout)
        ucap = 1
        while ucap < len(rows):
            ucap *= 2
        sentinel = -1 if mesh is not None else host.shape[0]
        idx = np.full(ucap, sentinel, np.int32)
        idx[: len(rows)] = rows
        upd = np.zeros((ucap, host.shape[1]), np.float32)
        upd[: len(rows)] = host[rows]
        self.index_row_updates += 1
        if mesh is not None:
            return shard_ops.sharded_scatter_rows(
                cached, idx, upd, mesh=mesh, axis=self.mesh_axis), n
        return ops.scatter_normalize_rows(
            cached, jnp.asarray(idx), jnp.asarray(upd)), n

    def fact_index_device(self):
        """(device-resident L2-normalized fact matrix, valid count). Use with
        ``topk_sim(..., normalize=False)``; rows are normalized with the same
        formula the kernel applies, so scores match the host path bit-for-
        bit. Dead facts' rows are zero vectors (score 0 after masking).

        With a mesh attached the matrix is round-robin row-sharded and must
        be scanned through ``shard_ops.sharded_topk_sim`` (which returns
        global row ids); the Retriever dispatches on ``forest.mesh``."""
        n = len(self.facts)
        self._fact_dev, self._fact_dev_rows = self._sync_device(
            self.fact_emb, n, self._fact_dev, self._fact_dev_rows,
            self._fact_dev_dirty, sharded=True)
        return self._fact_dev, n

    def root_index_device(self):
        """(device-resident normalized root matrix, valid count, tree order).
        Same contract as fact_index_device for the tree-root index."""
        n = len(self._tree_order)
        self._root_dev, self._root_dev_rows = self._sync_device(
            self._root_matrix, n, self._root_dev, self._root_dev_rows,
            self._root_dev_dirty)
        return self._root_dev, n, list(self._tree_order)

    # ------------------------------------------------------------------
    # scene routing state
    # ------------------------------------------------------------------
    def route_scene(self, emb: np.ndarray) -> int:
        """Nearest-centroid online clustering; returns scene id."""
        thr = self.config.scene_sim_threshold
        if self.scene_centroids.shape[0]:
            sims = self.scene_centroids @ emb
            best = int(np.argmax(sims))
            if sims[best] >= thr:
                c = self.scene_counts[best]
                self.scene_centroids[best] = (self.scene_centroids[best] * c + emb) / (c + 1)
                norm = np.linalg.norm(self.scene_centroids[best]) + 1e-6
                self.scene_centroids[best] /= norm
                self.scene_counts[best] += 1
                return best
        self.scene_centroids = np.concatenate([self.scene_centroids, emb[None]], axis=0)
        self.scene_counts.append(1)
        return self.scene_centroids.shape[0] - 1

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------
    def scale_stats(self) -> Dict[str, int]:
        return {
            "facts": sum(self.fact_alive),
            "trees": sum(1 for t in self.trees.values() if t.root >= 0),
            "nodes": sum(t.num_nodes for t in self.trees.values()),
            "cells": len(self.cells),
        }
