"""Write path stage 1: parallel chunk extraction + cell materialization
(paper §4.1).

Sessions are partitioned into fixed-size b-turn chunks (Eq. 5; default b=2,
the Appendix-C operating point). Chunks are *independent*: the whole
session's chunks are embedded in ONE batched encoder forward — the TPU-native
form of the paper's concurrent extraction calls (DESIGN.md §3). The
dependency depth of extraction is therefore 1, vs O(M) for serialized
baselines.

An LLM output-budget constraint is modeled: each extraction call returns at
most `max_facts_per_call` candidates (surplus statements in oversized chunks
are dropped) — this is what degrades Ent-GR at large chunk sizes in the
paper's Table 8, and benchmarks/bench_chunk_sweep.py reproduces it.
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

from repro.core.types import DialogueCell, RawCandidate, Session, WriteStats
from repro.data import templates as T

DEFAULT_MAX_FACTS_PER_CALL = 6


def chunk_session(session: Session, b: int) -> List[Tuple[int, str, float]]:
    """Partition into ceil(n/b) chunks of b turns: (chunk_idx, text, ts)."""
    chunks = []
    turns = session.turns
    for j in range(0, len(turns), b):
        grp = turns[j:j + b]
        text = " ".join(f"[{t.role}] {t.text}" for t in grp)
        chunks.append((j // b, text, grp[0].ts))
    return chunks


def extract_candidates(
    chunk_text: str,
    source: Tuple[str, int],
    max_facts: int = DEFAULT_MAX_FACTS_PER_CALL,
) -> List[RawCandidate]:
    """One extraction call (deterministic LLM stand-in). Output budget capped
    at `max_facts` candidates — surplus is dropped (recency-last)."""
    cands = T.parse_statement(chunk_text, source)
    return cands[:max_facts]


class ParallelExtractor:
    """Batched (= parallel) chunk extraction."""

    def __init__(self, encoder, chunk_turns: int = 2,
                 max_facts_per_call: int = DEFAULT_MAX_FACTS_PER_CALL,
                 concurrency: int = 64):
        self.encoder = encoder
        self.b = chunk_turns
        self.max_facts = max_facts_per_call
        self.concurrency = concurrency

    def extract_session(self, session: Session):
        """Returns (candidates, cells, stats). One batched encode for chunk
        cells + one for candidate texts: dependency depth 1."""
        t0 = time.perf_counter()
        chunks = chunk_session(session, self.b)
        texts = [c[1] for c in chunks]
        embs = self.encoder.encode(texts)             # parallel: one batch
        cells = [
            DialogueCell(-1, session.session_id, idx, text, ts, embs[i])
            for i, (idx, text, ts) in enumerate(chunks)
        ]
        candidates: List[RawCandidate] = []
        for idx, text, ts in chunks:
            candidates.extend(
                extract_candidates(text, (session.session_id, idx), self.max_facts)
            )
        fact_embs = (
            self.encoder.encode([c.text for c in candidates])
            if candidates else None
        )
        stats = WriteStats(
            wall_s=time.perf_counter() - t0,
            llm_dependency_depth=1,
            facts_written=len(candidates),
        )
        return candidates, fact_embs, cells, stats


class SequentialExtractor:
    """Serialized extraction (what a single LLM pass over the session looks
    like) — used as the ablation/baseline cost model."""

    def __init__(self, encoder, chunk_turns: int = 2,
                 max_facts_per_call: int = DEFAULT_MAX_FACTS_PER_CALL):
        self.encoder = encoder
        self.b = chunk_turns
        self.max_facts = max_facts_per_call

    def extract_session(self, session: Session):
        t0 = time.perf_counter()
        chunks = chunk_session(session, self.b)
        cells, candidates = [], []
        for idx, text, ts in chunks:
            emb = self.encoder.encode([text], sequential=True)[0]  # one-by-one
            cells.append(DialogueCell(-1, session.session_id, idx, text, ts, emb))
            candidates.extend(
                extract_candidates(text, (session.session_id, idx), self.max_facts)
            )
        fact_embs = (
            self.encoder.encode([c.text for c in candidates])
            if candidates else None
        )
        stats = WriteStats(
            wall_s=time.perf_counter() - t0,
            llm_dependency_depth=len(chunks),
            facts_written=len(candidates),
        )
        return candidates, fact_embs, cells, stats
