"""Write path stage 1: parallel chunk extraction + cell materialization
(paper §4.1).

Sessions are partitioned into fixed-size b-turn chunks (Eq. 5; default b=2,
the Appendix-C operating point). Chunks are *independent*: the whole
session's chunks are embedded in ONE batched encoder forward — the TPU-native
form of the paper's concurrent extraction calls (DESIGN.md §3). The
dependency depth of extraction is therefore 1, vs O(M) for serialized
baselines.

An LLM output-budget constraint is modeled: each extraction call returns at
most `max_facts_per_call` candidates (surplus statements in oversized chunks
are dropped) — this is what degrades Ent-GR at large chunk sizes in the
paper's Table 8, and benchmarks/bench_chunk_sweep.py reproduces it.
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

from repro.core.types import DialogueCell, RawCandidate, Session, WriteStats
from repro.data import templates as T

DEFAULT_MAX_FACTS_PER_CALL = 6


def chunk_session(session: Session, b: int) -> List[Tuple[int, str, float]]:
    """Partition into ceil(n/b) chunks of b turns: (chunk_idx, text, ts)."""
    chunks = []
    turns = session.turns
    for j in range(0, len(turns), b):
        grp = turns[j:j + b]
        text = " ".join(f"[{t.role}] {t.text}" for t in grp)
        chunks.append((j // b, text, grp[0].ts))
    return chunks


def extract_candidates(
    chunk_text: str,
    source: Tuple[str, int],
    max_facts: int = DEFAULT_MAX_FACTS_PER_CALL,
) -> List[RawCandidate]:
    """One extraction call (deterministic LLM stand-in). Output budget capped
    at `max_facts` candidates — surplus is dropped (recency-last)."""
    cands = T.parse_statement(chunk_text, source)
    return cands[:max_facts]


class SessionExtraction:
    """Per-session extraction output (one element of an extract_sessions
    batch): mirrors the extract_session tuple, plus the session itself."""

    __slots__ = ("session", "candidates", "fact_embs", "cells")

    def __init__(self, session, candidates, fact_embs, cells):
        self.session = session
        self.candidates = candidates
        self.fact_embs = fact_embs
        self.cells = cells


class ParallelExtractor:
    """Batched (= parallel) chunk extraction."""

    def __init__(self, encoder, chunk_turns: int = 2,
                 max_facts_per_call: int = DEFAULT_MAX_FACTS_PER_CALL,
                 concurrency: int = 64):
        self.encoder = encoder
        self.b = chunk_turns
        self.max_facts = max_facts_per_call
        self.concurrency = concurrency

    def extract_session(self, session: Session):
        """Returns (candidates, cells, stats). One batched encode for chunk
        cells + one for candidate texts: dependency depth 1."""
        t0 = time.perf_counter()
        chunks = chunk_session(session, self.b)
        texts = [c[1] for c in chunks]
        embs = self.encoder.encode(texts)             # parallel: one batch
        cells = [
            DialogueCell(-1, session.session_id, idx, text, ts, embs[i])
            for i, (idx, text, ts) in enumerate(chunks)
        ]
        candidates: List[RawCandidate] = []
        for idx, text, ts in chunks:
            candidates.extend(
                extract_candidates(text, (session.session_id, idx), self.max_facts)
            )
        fact_embs = (
            self.encoder.encode([c.text for c in candidates])
            if candidates else None
        )
        stats = WriteStats(
            wall_s=time.perf_counter() - t0,
            llm_dependency_depth=1,
            facts_written=len(candidates),
        )
        return candidates, fact_embs, cells, stats

    def extract_sessions(self, sessions: Sequence[Session]):
        """Cross-session batched extraction: the union of every session's
        chunk texts AND candidate texts is embedded in ONE encoder forward
        (chunks are independent across sessions just as within one, and
        candidate parsing is host-side, so nothing serializes on the model).
        Dependency depth stays 1 regardless of batch size.

        Returns ([SessionExtraction, ...], WriteStats)."""
        t0 = time.perf_counter()
        per_chunks: List[List[Tuple[int, str, float]]] = []
        per_cands: List[List[RawCandidate]] = []
        texts: List[str] = []
        for session in sessions:
            chunks = chunk_session(session, self.b)
            per_chunks.append(chunks)
            texts.extend(c[1] for c in chunks)
            cands: List[RawCandidate] = []
            for idx, text, ts in chunks:
                cands.extend(
                    extract_candidates(text, (session.session_id, idx), self.max_facts)
                )
            per_cands.append(cands)
        for cands in per_cands:
            texts.extend(c.text for c in cands)
        embs = self.encoder.encode(texts)             # ONE cross-session batch

        out: List[SessionExtraction] = []
        pos = 0
        for session, chunks in zip(sessions, per_chunks):
            cells = [
                DialogueCell(-1, session.session_id, idx, text, ts, embs[pos + i])
                for i, (idx, text, ts) in enumerate(chunks)
            ]
            pos += len(chunks)
            out.append(SessionExtraction(session, None, None, cells))
        for ext, cands in zip(out, per_cands):
            ext.candidates = cands
            ext.fact_embs = embs[pos:pos + len(cands)] if cands else None
            pos += len(cands)

        stats = WriteStats(
            wall_s=time.perf_counter() - t0,
            llm_dependency_depth=1 if texts else 0,
            facts_written=sum(len(c) for c in per_cands),
        )
        return out, stats


class SequentialExtractor:
    """Serialized extraction (what a single LLM pass over the session looks
    like) — used as the ablation/baseline cost model."""

    def __init__(self, encoder, chunk_turns: int = 2,
                 max_facts_per_call: int = DEFAULT_MAX_FACTS_PER_CALL):
        self.encoder = encoder
        self.b = chunk_turns
        self.max_facts = max_facts_per_call

    def extract_session(self, session: Session):
        t0 = time.perf_counter()
        chunks = chunk_session(session, self.b)
        cells, candidates = [], []
        for idx, text, ts in chunks:
            emb = self.encoder.encode([text], sequential=True)[0]  # one-by-one
            cells.append(DialogueCell(-1, session.session_id, idx, text, ts, emb))
            candidates.extend(
                extract_candidates(text, (session.session_id, idx), self.max_facts)
            )
        fact_embs = (
            self.encoder.encode([c.text for c in candidates])
            if candidates else None
        )
        stats = WriteStats(
            wall_s=time.perf_counter() - t0,
            llm_dependency_depth=len(chunks),
            facts_written=len(candidates),
        )
        return candidates, fact_embs, cells, stats

    def extract_sessions(self, sessions: Sequence[Session]):
        """Serialized fallback: per-session extraction in a loop (the cost
        model stays honest — no cross-session batching)."""
        out: List[SessionExtraction] = []
        agg = WriteStats()
        for session in sessions:
            candidates, fact_embs, cells, st = self.extract_session(session)
            out.append(SessionExtraction(session, candidates, fact_embs, cells))
            agg.add(st)
        return out, agg
