"""MemTree: balanced k-ary temporal index over one scope (paper §3.2, §4.2).

Structure lives on the host (a production serving stack keeps index metadata
host-side); embedding math runs on device via kernels (`tree_refresh`,
`topk_sim`). The tree is a B-tree over the time axis:

  * leaves (level 0) hold evidence items in temporal order,
  * internal nodes summarize contiguous time intervals,
  * inserts descend to the covering level-1 node and split upward when a node
    exceeds the branching factor k — structural inserts touch one
    leaf-to-root path: O(log_k N) dependent depth,
  * semantic refresh is LAZY: inserts only mark ancestor paths dirty
    (coalesced); `Forest.flush` regenerates dirty summaries bottom-up,
    level-parallel, batched across trees.

Time-ordered appends (the common case for an online session stream) take the
rightmost-path fast path — the same reason LSM/B+ bulk loads are cheap.
"""
from __future__ import annotations

import bisect
import math
import re
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

import numpy as np

SUMMARY_CHAR_BUDGET = 320

# content-word tokenizer shared by browse intent matching (retrieval) and the
# per-node word-set caches below — one definition so cached node sets and
# query sets are always comparable
_WORD_RE = re.compile(r"[a-z]+")
STOPWORDS = frozenset(
    "what where when did does do is was the a an to of in on as now first "
    "before after moving become becoming switch switched start started who "
    "which place over since".split()
)


def content_words(text: str) -> FrozenSet[str]:
    return frozenset(
        w for w in _WORD_RE.findall(text.lower()) if w not in STOPWORDS
    )


class TreeArena:
    """One MemTree. Node storage is struct-of-lists indexed by node id."""

    __slots__ = (
        "tree_id", "scope_key", "kind", "k", "dim",
        "parent", "children", "level", "start_ts", "end_ts",
        "payload", "text", "alive", "emb", "dirty", "root", "_n",
        "_deleted_any", "_node_words", "_node_lower",
    )

    def __init__(self, tree_id: int, scope_key: str, kind: str, k: int, dim: int):
        # k >= 3 so that splitting k+1 children yields min-fill 2 on both
        # sides — the classic B-tree order requirement. k = 2 admits 1-child
        # chains with adversarial (out-of-order) inserts and loses the
        # O(log N) height bound (found by hypothesis).
        assert k >= 3, f"branching factor must be >= 3, got {k}"
        self.tree_id = tree_id
        self.scope_key = scope_key
        self.kind = kind          # "entity" | "scene" | "session"
        self.k = k
        self.dim = dim
        self.parent: List[int] = []
        self.children: List[List[int]] = []
        self.level: List[int] = []
        self.start_ts: List[float] = []
        self.end_ts: List[float] = []
        self.payload: List[Optional[int]] = []   # leaf -> item id
        self.text: List[str] = []
        self.alive: List[bool] = []
        self.emb = np.zeros((8, dim), np.float32)
        self.dirty: Set[int] = set()
        self.root: int = -1
        self._n = 0
        self._deleted_any = False
        # memoized per-node text views (browse intent matching re-reads the
        # same node texts for every query); invalidated by refresh_text
        self._node_words: Dict[int, FrozenSet[str]] = {}
        self._node_lower: Dict[int, str] = {}

    # ------------------------------------------------------------------
    # node allocation
    # ------------------------------------------------------------------
    def _alloc(self, level: int, ts: Tuple[float, float], text: str = "",
               payload: Optional[int] = None, emb: Optional[np.ndarray] = None) -> int:
        nid = self._n
        self._n += 1
        self.parent.append(-1)
        self.children.append([])
        self.level.append(level)
        self.start_ts.append(ts[0])
        self.end_ts.append(ts[1])
        self.payload.append(payload)
        self.text.append(text)
        self.alive.append(True)
        if nid >= self.emb.shape[0]:
            self.emb = np.concatenate(
                [self.emb, np.zeros_like(self.emb)], axis=0
            )
        if emb is not None:
            self.emb[nid] = emb
        return nid

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return sum(self.alive)

    @property
    def num_leaves(self) -> int:
        return sum(1 for i in range(self._n) if self.alive[i] and self.level[i] == 0)

    @property
    def height(self) -> int:
        return self.level[self.root] if self.root >= 0 else 0

    def leaves_in_order(self, node: Optional[int] = None) -> List[int]:
        if self.root < 0:
            return []
        node = self.root if node is None else node
        if self.level[node] == 0:
            return [node]
        out: List[int] = []
        for c in self.children[node]:
            out.extend(self.leaves_in_order(c))
        return out

    def root_emb(self) -> np.ndarray:
        return self.emb[self.root] if self.root >= 0 else np.zeros(self.dim, np.float32)

    # ------------------------------------------------------------------
    # browse support: memoized text views + packed child gathers
    # ------------------------------------------------------------------
    def node_words(self, node: int) -> FrozenSet[str]:
        """Memoized content-word set of a node's summary/leaf text."""
        w = self._node_words.get(node)
        if w is None:
            w = content_words(self.text[node])
            self._node_words[node] = w
        return w

    def node_text_lower(self, node: int) -> str:
        """Memoized lowercased node text (anchor substring matching)."""
        t = self._node_lower.get(node)
        if t is None:
            t = self.text[node].lower()
            self._node_lower[node] = t
        return t

    def pack_children(self, nodes: List[int], k_pad: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Contiguous child-index arrays for the level-synchronous browse:
        (idx (F, k_pad) int32, mask (F, k_pad) f32, emb (F, k_pad, D) f32).
        The embedding gather is ONE fancy-index over the arena (padding slots
        reuse index 0 and are masked), so packing cost scales with the
        frontier, not with per-child Python calls."""
        F = len(nodes)
        idx = np.zeros((F, k_pad), np.int32)
        mask = np.zeros((F, k_pad), np.float32)
        for i, n in enumerate(nodes):
            kids = self.children[n]
            c = min(len(kids), k_pad)
            idx[i, :c] = kids[:c]
            mask[i, :c] = 1.0
        return idx, mask, self.emb[idx]

    # ------------------------------------------------------------------
    # insertion
    # ------------------------------------------------------------------
    def insert_leaf(self, item_id: int, ts: float, emb: np.ndarray, text: str) -> int:
        """Structural insert + dirty-path marking. Returns the leaf id.
        Dependent depth: one leaf-to-root path = O(log_k N)."""
        leaf = self._alloc(0, (ts, ts), text=text, payload=item_id, emb=emb)
        if self.root < 0:
            self.root = leaf
            self.dirty.add(leaf)
            return leaf
        if self.level[self.root] == 0:
            # second item: grow an internal root above the two leaves
            old = self.root
            new_root = self._alloc(1, (min(self.start_ts[old], ts), max(self.end_ts[old], ts)))
            kids = sorted([old, leaf], key=lambda n: self.start_ts[n])
            self.children[new_root] = kids
            for c in kids:
                self.parent[c] = new_root
            self.root = new_root
            self._mark_dirty_path(new_root)
            return leaf

        target = self._find_level1(ts)
        self._attach(target, leaf)
        self._split_up(target)
        self._mark_dirty_path(self.parent[leaf])
        return leaf

    def _find_level1(self, ts: float) -> int:
        """Descend to the level-1 node covering ts (rightmost fast path for
        appends)."""
        node = self.root
        while self.level[node] > 1:
            kids = self.children[node]
            # pick the last child whose start <= ts, else the first
            chosen = kids[0]
            for c in kids:
                if self.start_ts[c] <= ts:
                    chosen = c
                else:
                    break
            node = chosen
        return node

    def _attach(self, parent: int, child: int) -> None:
        kids = self.children[parent]
        keys = [self.start_ts[c] for c in kids]
        pos = bisect.bisect_right(keys, self.start_ts[child])
        kids.insert(pos, child)
        self.parent[child] = parent
        self._update_range_up(parent)

    def _update_range_up(self, node: int) -> None:
        while node != -1:
            kids = self.children[node]
            if kids:
                self.start_ts[node] = self.start_ts[kids[0]]
                self.end_ts[node] = max(self.end_ts[c] for c in kids)
            node = self.parent[node]

    def _split_up(self, node: int) -> None:
        """B-tree split cascade: node with > k children splits in half."""
        while node != -1 and len(self.children[node]) > self.k:
            kids = self.children[node]
            half = len(kids) // 2
            left_kids, right_kids = kids[:half], kids[half:]
            right = self._alloc(self.level[node],
                                (self.start_ts[right_kids[0]],
                                 max(self.end_ts[c] for c in right_kids)))
            self.children[node] = left_kids
            self.children[right] = right_kids
            for c in right_kids:
                self.parent[c] = right
            self.end_ts[node] = max(self.end_ts[c] for c in left_kids)
            self.start_ts[node] = self.start_ts[left_kids[0]]
            # the left half keeps the old summary but lost half its
            # children — without a dirty mark it would stay stale through
            # the next flush (its ancestors are on the insert path, so the
            # dirty invariant still holds)
            self.dirty.add(node)
            p = self.parent[node]
            if p == -1:
                new_root = self._alloc(self.level[node] + 1,
                                       (self.start_ts[node], self.end_ts[right]))
                self.children[new_root] = [node, right]
                self.parent[node] = new_root
                self.parent[right] = new_root
                self.root = new_root
                self.dirty.add(right)
                self._mark_dirty_path(new_root)
                return
            kids_p = self.children[p]
            kids_p.insert(kids_p.index(node) + 1, right)
            self.parent[right] = p
            self.dirty.add(right)
            # p's child set changed; mark its path explicitly — the caller's
            # leaf-path marking would break early at the already-dirty half
            # and leave p (and its ancestors) stale
            self._mark_dirty_path(p)
            node = p

    def _mark_dirty_path(self, node: int) -> None:
        """Coalesced dirty marking: stop when an already-dirty ancestor is
        found *and* everything above it is dirty too (paper: repeated dirty
        marks on overlapping paths are coalesced)."""
        while node != -1:
            if node in self.dirty:
                # ancestors are guaranteed dirty already (invariant)
                break
            self.dirty.add(node)
            node = self.parent[node]

    # ------------------------------------------------------------------
    # deletion (lifecycle maintenance)
    # ------------------------------------------------------------------
    def delete_leaf(self, leaf: int) -> None:
        assert self.level[leaf] == 0 and self.alive[leaf]
        self._deleted_any = True
        self.alive[leaf] = False
        p = self.parent[leaf]
        if p == -1:               # tree had a single leaf
            self.root = -1
            self.dirty.discard(leaf)
            return
        self.children[p].remove(leaf)
        self.dirty.discard(leaf)
        node = p
        while node != -1 and not self.children[node]:
            self.alive[node] = False
            self.dirty.discard(node)
            q = self.parent[node]
            if q == -1:
                self.root = -1
                return
            self.children[q].remove(node)
            node = q
        # collapse a root with a single child
        while self.root != -1 and self.level[self.root] > 0 and len(self.children[self.root]) == 1:
            only = self.children[self.root][0]
            self.alive[self.root] = False
            self.dirty.discard(self.root)
            self.parent[only] = -1
            self.root = only
        if node != -1:
            self._update_range_up(node)
            self._mark_dirty_path(node)
        elif self.root != -1:
            self._mark_dirty_path(self.root)

    # ------------------------------------------------------------------
    # refresh support (called by Forest.flush)
    # ------------------------------------------------------------------
    def dirty_by_level(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for n in self.dirty:
            if self.alive[n]:
                out.setdefault(self.level[n], []).append(n)
        return out

    def refresh_text(self, node: int) -> None:
        """Regenerate the interval summary text from children (token-budget
        concat — the text channel of SummarizeChildren)."""
        parts = []
        for c in self.children[node]:
            t = self.text[c]
            if t:
                parts.append(t)
        joined = " | ".join(parts)
        self.text[node] = joined[:SUMMARY_CHAR_BUDGET]
        self._node_words.pop(node, None)
        self._node_lower.pop(node, None)

    def check_invariants(self) -> None:
        """Test hook: temporal leaf order, parent ranges, balance bound."""
        if self.root < 0:
            return
        leaves = self.leaves_in_order()
        ts = [self.start_ts[l] for l in leaves]
        assert ts == sorted(ts), "leaf temporal order violated"
        n = len(leaves)
        if n >= 2 and not self._deleted_any:
            # B-tree with max fanout k and splits in half: height bound
            bound = math.ceil(math.log(max(n, 2), max(2, (self.k + 1) // 2))) + 1
            assert self.height <= bound, (self.height, bound, n)
        for i in range(self._n):
            if not self.alive[i] or self.level[i] == 0:
                continue
            kids = self.children[i]
            assert kids, f"internal node {i} with no children"
            assert len(kids) <= self.k, "fanout exceeded"
            assert self.start_ts[i] == self.start_ts[kids[0]]
            for c in kids:
                assert self.parent[c] == i
                assert self.level[c] == self.level[i] - 1, "uneven levels"
