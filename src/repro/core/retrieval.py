"""Query path: forest recall + tree browse (paper §4.3), batched.

Forest recall (Eq. 7): union of root recall (tree-level relevance) and
fact-to-tree recall (evidence-level relevance mapped back through placement),
scored with the fused `topk_sim` kernel against the Forest's DEVICE-RESIDENT
normalized indexes (no per-query host->device transfer or re-normalization).

Browse modes (paper Table 7 ablation):
  * flat        — top-k facts from the flat index, no tree structure
  * root-only   — recalled trees' root summaries as evidence, no descent
  * emb         — embedding-similarity beam descent
  * emb+planner — embedding descent with the planner's rewritten query vector
                  (the paper finds this HURTS: vector similarity can't carry
                  structured browse intent — reproduced here)
  * llm         — guided descent: child scores combine embedding similarity
                  with structured temporal intent (before/after/first/when +
                  anchor matching), the deterministic stand-in for LLM branch
                  selection (DESIGN.md §7)
  * llm+planner — llm browse + per-tree subqueries from root summaries
                  (anchor terms weighted, tree time-range aware)

The tree browse is LEVEL-SYNCHRONOUS and batched: every (query, tree) pair is
a browse *lane*, and each descent round packs all lanes' expandable beam
nodes into one padded (F, K, D) child-embedding gather scored by a single
``browse_scores`` kernel launch — the read-path twin of the flush kernel's
cross-tree batch dimension. Intent/anchor bonuses stay on host as vectorized
numpy over the packed frontier (with per-node content-word sets memoized on
the TreeArena). ``retrieve`` and ``retrieve_batch`` share this engine, so
batched results are identical to the single-query path by construction.

Multi-device serve: when the Forest carries a mesh (``Forest.set_mesh``),
the fact-index scan runs shard-local + cross-device candidate merge
(``shard_ops.sharded_topk_sim``) and the packed browse frontier shards over
the same data axis — both exactly result-identical to mesh=None thanks to
row-local math and the shared deterministic top-k tie-break.

The answerer is SHARED across all memory systems benchmarked (baselines
included): given retrieved canonical facts it applies query semantics
(current/before/when/first). Accuracy therefore measures retrieval quality —
the paper's framing.
"""
from __future__ import annotations

import re
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.config import MemForestConfig
from repro.core.forest import Forest
from repro.core.memtree import TreeArena, content_words as _content_words
from repro.core.types import CanonicalFact, Query, QueryResult
from repro.data import templates as T
from repro.kernels import ops, shard_ops

_BEFORE_RE = re.compile(r"before (?:moving to |becoming |project )?([A-Za-z ]+?)\?")
_WHEN_RE = re.compile(r"^When did")
_FIRST_RE = re.compile(r"first")
_NOW_RE = re.compile(r"now\?$")


class TemporalIntent:
    __slots__ = ("relation", "anchor", "attribute")

    def __init__(self, relation: str, anchor: Optional[str], attribute: str = ""):
        self.relation = relation      # before | when | first | current | none
        self.anchor = anchor
        self.attribute = attribute    # inferred topical family (may be "")

    @staticmethod
    def parse(text: str) -> "TemporalIntent":
        attr = T.infer_attribute(text)
        m = _BEFORE_RE.search(text)
        if m:
            return TemporalIntent("before", m.group(1).strip(), attr)
        if _WHEN_RE.search(text):
            m2 = re.search(r"(?:move to|become|switch to project|preferring) ([A-Za-z ]+?)\?", text)
            return TemporalIntent("when", m2.group(1).strip() if m2 else None, attr)
        if _FIRST_RE.search(text):
            return TemporalIntent("first", None, attr)
        if _NOW_RE.search(text):
            return TemporalIntent("current", None, attr)
        return TemporalIntent("none", None, attr)

    def matches_attr(self, text: str) -> bool:
        if not self.attribute:
            return False
        kws = T.ATTR_KEYWORDS[self.attribute]
        return bool(set(re.findall(r"[a-z]+", text.lower())) & kws)


class _Lane:
    """One (query, tree) pair of the level-synchronous batched browse."""

    __slots__ = ("qi", "tree", "q", "intent", "q_words", "beam", "next_beam",
                 "collected")

    def __init__(self, qi: int, tree: TreeArena, q: np.ndarray,
                 intent: Optional[TemporalIntent], q_words):
        self.qi = qi
        self.tree = tree
        self.q = q                    # browse query vector (planner may mix)
        self.intent = intent          # None for emb browse
        self.q_words = q_words
        self.beam: List[Tuple[int, float]] = []
        self.next_beam: List[Tuple[int, float]] = []
        self.collected: Dict[int, float] = {}


class Retriever:
    def __init__(self, forest: Forest, encoder, config: MemForestConfig):
        self.forest = forest
        self.encoder = encoder
        self.config = config
        self.browse_launches = 0      # benchmarks read this

    # ------------------------------------------------------------------
    def retrieve(self, text: str, mode: Optional[str] = None,
                 final_topk: Optional[int] = None) -> Tuple[List[CanonicalFact], List[str], Dict]:
        """Single-query path. Returns (facts, evidence_texts, stats). Shares
        the lane engine with retrieve_batch (a batch of one), so batching is
        result-invariant by construction."""
        return self.retrieve_batch([text], mode=mode, final_topk=final_topk)[0]

    def _stats(self, t0, calls0) -> Dict:
        return {
            "retrieval_s": time.perf_counter() - t0,
            "encoder_calls": self.encoder.stats.calls - calls0,
        }

    # ------------------------------------------------------------------
    def retrieve_batch(self, texts: List[str], mode: Optional[str] = None,
                       final_topk: Optional[int] = None):
        """Batched retrieval for serving throughput: ONE encoder forward, ONE
        fused topk_sim per index over the device-resident normalized fact and
        root matrices for all queries (the kernel's Q dimension), ONE planner
        forward across every (query, tree) lane, and a level-synchronous
        browse that scores each depth level of every lane in a single
        ``browse_scores`` launch. Returns a list of (facts, evidence, stats)
        like retrieve()."""
        cfg = self.config
        mode = mode or cfg.browse_mode
        topk = final_topk or cfg.final_topk
        t0 = time.perf_counter()
        calls0 = self.encoder.stats.calls
        if not texts:
            return []

        q_embs = self.encoder.encode(texts)              # one batch
        fact_dev, n_facts = self.forest.fact_index_device()
        root_dev, n_trees, order = self.forest.root_index_device()
        qd = ops.normalize_rows(jnp.asarray(q_embs))

        flat_idx = None
        if n_facts:
            k_facts = min(max(topk, cfg.fact_recall_topk), n_facts)
            if self.forest.mesh is not None:
                # mesh-sharded scan: shard-local top-k over the round-robin
                # sharded fact index + cross-device candidate merge; exactly
                # result-identical to the single-device path (shared
                # deterministic tie-break: score desc, row id asc)
                _, flat_idx = shard_ops.sharded_topk_sim(
                    qd, fact_dev, k_facts, mesh=self.forest.mesh,
                    axis=self.forest.mesh_axis, num_valid=n_facts,
                    impl=self.forest.kernel_impl,
                )
            else:
                _, flat_idx = ops.topk_sim(
                    qd, fact_dev, k_facts,
                    normalize=False, num_valid=n_facts,
                    impl=self.forest.kernel_impl,
                )
            flat_idx = np.asarray(flat_idx)
        root_vals = root_idx = None
        if n_trees:
            root_vals, root_idx = ops.topk_sim(
                qd, root_dev, min(cfg.forest_recall_topk * 3, n_trees),
                normalize=False, num_valid=n_trees, impl=self.forest.kernel_impl,
            )
            root_vals = np.asarray(root_vals)
            root_idx = np.asarray(root_idx)

        per_q_flat: List[List[CanonicalFact]] = []
        for qi in range(len(texts)):
            flat: List[CanonicalFact] = []
            if flat_idx is not None:
                for i in flat_idx[qi]:
                    if i >= 0 and self.forest.fact_alive[int(i)]:
                        flat.append(self.forest.facts[int(i)])
            per_q_flat.append(flat)

        if mode == "flat":
            pairs = [(flat[:topk], [f.text for f in flat[:topk]])
                     for flat in per_q_flat]
            stats = self._stats(t0, calls0)
            return [(f, e, stats) for f, e in pairs]

        intents = [TemporalIntent.parse(t) for t in texts]
        per_q_trees = [
            self._recall_from_scores(
                q_embs[qi], per_q_flat[qi],
                root_vals[qi] if root_vals is not None else None,
                root_idx[qi] if root_idx is not None else None, order)
            for qi in range(len(texts))
        ]

        if mode == "root-only":
            pairs = []
            for trees in per_q_trees:
                ev = [t.text[t.root][:200] if t.root >= 0 else "" for t in trees]
                pairs.append((self._facts_from_summaries(trees, topk), ev))
            stats = self._stats(t0, calls0)
            return [(f, e, stats) for f, e in pairs]

        use_intent = mode.startswith("llm")
        lanes: List[_Lane] = []
        per_q_lanes: List[List[_Lane]] = [[] for _ in texts]
        for qi, trees in enumerate(per_q_trees):
            q_words = _content_words(texts[qi]) if use_intent else frozenset()
            for tree in trees:
                lane = _Lane(qi, tree, q_embs[qi],
                             intents[qi] if use_intent else None, q_words)
                lanes.append(lane)
                per_q_lanes[qi].append(lane)

        if mode.endswith("+planner") and lanes:
            self._plan_lanes(lanes, texts, mode)

        self._browse_lanes(lanes)

        pairs = []
        for qi in range(len(texts)):
            leaves: List[Tuple[TreeArena, int, float]] = []
            for lane in per_q_lanes[qi]:
                best = sorted(lane.collected.items(), key=lambda kv: -kv[1])[:16]
                leaves.extend((lane.tree, n, s) for n, s in best)
                if use_intent:
                    leaves.extend(self._temporal_navigate(
                        lane.tree, intents[qi], lane.q_words))
            pairs.append(self._resolve(leaves, q_embs[qi], intents[qi], topk,
                                       use_intent=use_intent))
        stats = self._stats(t0, calls0)
        return [(facts, ev, stats) for facts, ev in pairs]

    # ------------------------------------------------------------------
    def _recall_from_scores(self, q_emb, flat_facts, root_vals_row,
                            root_idx_row, order) -> List[TreeArena]:
        """Forest recall from the precomputed fused topk_sim results: root
        scores come straight from the kernel's values (no re-dotting), and
        the tree order is resolved once per batch (hoisted by the caller)."""
        cfg = self.config
        allowed = set(cfg.tree_families)
        scores: Dict[str, float] = {}
        if root_idx_row is not None:
            for v, i in zip(root_vals_row, root_idx_row):
                if i >= 0:
                    key = order[int(i)]
                    scores[key] = max(scores.get(key, -1e9), float(v))
        for f in flat_facts[: cfg.fact_recall_topk]:
            sim = float(f.emb @ q_emb)
            for scope_key, _leaf in self.forest.placement.get(("fact", f.fact_id), []):
                scores[scope_key] = max(scores.get(scope_key, -1e9), 0.95 * sim)
            # fact -> source-session recall (session trees host cells; the
            # facts' source refs map them back — keeps the fallback channel
            # recallable)
            if "session" in allowed:
                for sid, _ in f.sources[:2]:
                    key = f"session:{sid}"
                    if key in self.forest.trees:
                        scores[key] = max(scores.get(key, -1e9), 0.9 * sim)
        # family filter BEFORE ranking (tree-family ablation must not starve)
        scores = {k: v for k, v in scores.items()
                  if self.forest.trees[k].kind in allowed}
        ranked = sorted(scores.items(), key=lambda kv: -kv[1])[: cfg.forest_recall_topk]
        return [self.forest.trees[k] for k, _ in ranked
                if self.forest.trees[k].root >= 0]

    # ------------------------------------------------------------------
    def _plan_lanes(self, lanes: List[_Lane], texts: List[str], mode: str) -> None:
        """Planner: one targeted subquery per (query, tree) lane, encoded in
        ONE batched forward across every lane of every query. For llm browse
        it sharpens the intent with the anchor term; for emb browse the
        rewrite is reduced to a vector mix (which is why emb+planner loses
        signal — paper §6.2)."""
        subs = []
        for lane in lanes:
            root_summary = lane.tree.text[lane.tree.root] if lane.tree.root >= 0 else ""
            subs.append(f"{texts[lane.qi]} [tree] {root_summary[:120]}")
        sub_embs = self.encoder.encode(subs)    # planner cost: 1 batched call
        if mode.startswith("emb"):
            for lane, sub_emb in zip(lanes, sub_embs):
                mix = 0.5 * lane.q + 0.5 * sub_emb
                mix /= (np.linalg.norm(mix) + 1e-6)
                lane.q = mix
        # llm: keep query vectors, the sharpened intent rides on the lane

    # ------------------------------------------------------------------
    def _browse_lanes(self, lanes: List[_Lane]) -> None:
        """Level-synchronous coarse-to-fine descent over every lane at once.
        Per round, all lanes' expandable beam nodes form ONE packed frontier
        scored by a single ``browse_scores`` launch; leaf hits collect into
        each lane's candidate set. Fills ``lane.collected``."""
        budget = self.config.browse_beam
        for lane in lanes:
            if lane.tree.root >= 0:
                lane.beam = [(lane.tree.root, 1.0)]
        active = [lane for lane in lanes if lane.beam]
        while active:
            frontier: List[Tuple[_Lane, int]] = []
            for lane in active:
                for node, _s in lane.beam:
                    if lane.tree.level[node] == 0:
                        s = float(lane.tree.emb[node] @ lane.q)
                        if lane.intent is not None:
                            s += self._leaf_bonus(lane.tree, node, lane.intent,
                                                  lane.q_words)
                        lane.collected[node] = max(
                            lane.collected.get(node, -1e9), s)
                    else:
                        frontier.append((lane, node))
            if not frontier:
                break
            sims_rows = self._score_frontier(frontier)
            for (lane, node), sims in zip(frontier, sims_rows):
                kids = lane.tree.children[node]
                if lane.intent is not None:
                    sims = sims + self._intent_bonus(lane.tree, kids,
                                                     lane.intent, lane.q_words)
                top = np.argsort(-sims, kind="stable")[:budget]
                lane.next_beam.extend((kids[i], float(sims[i])) for i in top)
            for lane in active:
                agg: Dict[int, float] = {}
                for n, s in lane.next_beam:
                    agg[n] = max(agg.get(n, -1e9), s)
                lane.beam = sorted(agg.items(), key=lambda kv: -kv[1])[: max(budget * 2, 6)]
                lane.next_beam = []
            active = [lane for lane in active if lane.beam]

    def _score_frontier(self, frontier: List[Tuple[_Lane, int]]) -> List[np.ndarray]:
        """Pack the frontier's child embeddings into one padded (F, K, D)
        tensor (one fancy-index gather per distinct tree) and score every
        (entry, child) pair in a single kernel launch. Shapes are bucketed to
        powers of two so the jit-compile set stays bounded."""
        F = len(frontier)
        kmax = max(len(lane.tree.children[n]) for lane, n in frontier)
        k_pad = 4
        while k_pad < kmax:
            k_pad *= 2
        cap = 8
        while cap < F:
            cap *= 2
        mesh = self.forest.mesh
        if mesh is not None:
            # lane padding to a shard multiple: the packed frontier splits
            # evenly over the mesh's data axis (padded rows are masked)
            cap = shard_ops.pad_rows(
                cap, shard_ops.mesh_shards(mesh, self.forest.mesh_axis))
        dim = self.config.embed_dim
        child = np.zeros((cap, k_pad, dim), np.float32)
        mask = np.zeros((cap, k_pad), np.float32)
        qm = np.zeros((cap, dim), np.float32)
        by_tree: Dict[int, Tuple[TreeArena, List[int], List[int]]] = {}
        for i, (lane, n) in enumerate(frontier):
            qm[i] = lane.q
            rows_nodes = by_tree.setdefault(
                id(lane.tree), (lane.tree, [], []))
            rows_nodes[1].append(i)
            rows_nodes[2].append(n)
        for tree, rows, nodes in by_tree.values():
            _idx, m, emb = tree.pack_children(nodes, k_pad)
            child[rows] = emb
            mask[rows] = m
        self.browse_launches += 1
        if mesh is not None:
            sims = np.asarray(shard_ops.sharded_browse_scores(
                child, qm, mask, mesh=mesh, axis=self.forest.mesh_axis,
                impl=self.forest.kernel_impl,
            ))
        else:
            sims = np.asarray(ops.browse_scores(
                jnp.asarray(child), jnp.asarray(qm), jnp.asarray(mask),
                impl=self.forest.kernel_impl,
            ))
        return [sims[i, : len(lane.tree.children[n])]
                for i, (lane, n) in enumerate(frontier)]

    def _intent_bonus(self, tree: TreeArena, kids: Sequence[int],
                      intent: TemporalIntent, q_words) -> np.ndarray:
        """The 'LLM reads child summaries' advantage: anchor-term + content-
        word matching and temporal-relation preferences that a bare vector
        score cannot carry. Node text views are memoized on the arena."""
        bonus = np.zeros(len(kids), np.float32)
        anchor = intent.anchor.lower() if intent.anchor else None
        for i, c in enumerate(kids):
            if anchor and anchor in tree.node_text_lower(c):
                bonus[i] += 0.30
            if q_words:
                overlap = len(q_words & tree.node_words(c))
                bonus[i] += min(0.05 * overlap, 0.20)
        if intent.relation == "first":
            bonus[0] += 0.15          # earliest interval
        elif intent.relation == "current":
            bonus[-1] += 0.15         # latest interval
        return bonus

    def _leaf_bonus(self, tree: TreeArena, leaf: int,
                    intent: TemporalIntent, q_words) -> float:
        b = 0.0
        if intent.anchor and intent.anchor.lower() in tree.node_text_lower(leaf):
            b += 0.30
        if q_words:
            b += min(0.05 * len(q_words & tree.node_words(leaf)), 0.20)
        return b

    def _temporal_navigate(self, tree: TreeArena, intent: TemporalIntent,
                           q_words) -> List[Tuple[TreeArena, int, float]]:
        """Explicit temporal navigation over the leaf order — what MemTree
        makes possible and flat stores cannot do (paper §4.3):
          * before/when: the anchor transition leaf + its predecessor,
          * current: the LAST topically-matching leaf,
          * first: the FIRST topically-matching leaf."""
        leaves = tree.leaves_in_order()
        out: List[Tuple[TreeArena, int, float]] = []
        if intent.relation in ("before", "when") and intent.anchor:
            anchor = intent.anchor.lower()
            for j, leaf in enumerate(leaves):
                if anchor in tree.node_text_lower(leaf):
                    out.append((tree, leaf, 1.0))
                    if j > 0:
                        out.append((tree, leaves[j - 1], 0.99))
                    break
        elif intent.relation == "current":
            for leaf in reversed(leaves):
                if intent.matches_attr(tree.text[leaf]) or (
                    q_words and len(q_words & tree.node_words(leaf)) >= 2
                ):
                    out.append((tree, leaf, 1.0))
                    break
        elif intent.relation == "first":
            for leaf in leaves:
                if intent.matches_attr(tree.text[leaf]) or (
                    q_words and len(q_words & tree.node_words(leaf)) >= 2
                ):
                    out.append((tree, leaf, 1.0))
                    break
        return out

    # ------------------------------------------------------------------
    def _resolve(self, leaves, q_emb, intent, topk, *, use_intent: bool):
        seen = set()
        scored: List[Tuple[float, CanonicalFact, str]] = []
        for tree, leaf, score in leaves:
            pay = tree.payload[leaf]
            if pay is None or not tree.alive[leaf]:
                continue
            if pay >= 0:  # fact
                f = self.forest.facts[pay]
                if not self.forest.fact_alive[f.fact_id] or ("f", pay) in seen:
                    continue
                seen.add(("f", pay))
                # navigation hits (score ~1.0) must survive the rerank: they
                # are the LLM browser's explicit selections
                s = float(f.emb @ q_emb) + (score * (0.5 if use_intent else 0.1))
                if use_intent and intent:
                    if intent.anchor and intent.anchor.lower() in f.text.lower():
                        s += 0.3
                    if intent.matches_attr(f.text):
                        s += 0.15
                scored.append((s, f, f.text))
            else:        # dialogue cell — re-extract facts (fallback channel)
                cell = self.forest.cells[-pay - 1]
                if ("c", cell.cell_id) in seen:
                    continue
                seen.add(("c", cell.cell_id))
                for cand in T.parse_statement(cell.text, (cell.session_id, cell.chunk_idx)):
                    ftmp = CanonicalFact(
                        fact_id=-1, text=cand.text, subject=cand.subject,
                        attribute=cand.attribute, value=cand.value, ts=cand.ts,
                        prev_value=cand.prev_value, sources=[cand.source],
                        emb=q_emb * 0,
                    )
                    scored.append((score * 0.5, ftmp, cell.text[:160]))
        scored.sort(key=lambda x: -x[0])
        top = scored[:topk]
        return [f for _, f, _ in top], [e for _, _, e in top]

    def _facts_from_summaries(self, trees: List[TreeArena], topk: int) -> List[CanonicalFact]:
        """root-only mode: parse what survives in root summaries (compressed,
        lossy — the paper's point)."""
        out = []
        for t in trees:
            if t.root < 0:
                continue
            for cand in T.parse_statement(t.text[t.root], ("root", 0)):
                out.append(CanonicalFact(
                    fact_id=-1, text=cand.text, subject=cand.subject,
                    attribute=cand.attribute, value=cand.value, ts=cand.ts,
                    prev_value=cand.prev_value, sources=[cand.source], emb=None,
                ))
        return out[:topk]


# ---------------------------------------------------------------------------
# shared answerer (all systems)
# ---------------------------------------------------------------------------
def answer_query(query: Query, facts: List[CanonicalFact]) -> str:
    """Apply query semantics over the retrieved fact set."""
    rel = [
        f for f in facts
        if f.subject.lower() == query.subject.lower()
        and f.attribute == query.attribute
    ]
    if not rel:
        return ""
    rel.sort(key=lambda f: f.ts)
    if query.qtype == "current":
        return rel[-1].value
    if query.qtype == "historical":
        anchor = (query.anchor_value or "").lower()
        for f in rel:
            if f.value.lower() == anchor and f.prev_value:
                return f.prev_value
        before = [f for f in rel if f.value.lower() != anchor]
        anchor_ts = next((f.ts for f in rel if f.value.lower() == anchor), None)
        if anchor_ts is not None:
            before = [f for f in before if f.ts < anchor_ts]
        return before[-1].value if before else ""
    if query.qtype == "transition_time":
        anchor = (query.anchor_value or "").lower()
        for f in rel:
            if f.value.lower() == anchor:
                return T.ts_to_date(f.ts)
        return ""
    if query.qtype in ("multi_session", "single_session"):
        return rel[0].value
    return rel[-1].value
