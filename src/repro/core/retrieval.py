"""Query path: forest recall + tree browse (paper §4.3).

Forest recall (Eq. 7): union of root recall (tree-level relevance) and
fact-to-tree recall (evidence-level relevance mapped back through placement),
scored with the fused `topk_sim` kernel.

Browse modes (paper Table 7 ablation):
  * flat        — top-k facts from the flat index, no tree structure
  * root-only   — recalled trees' root summaries as evidence, no descent
  * emb         — embedding-similarity beam descent
  * emb+planner — embedding descent with the planner's rewritten query vector
                  (the paper finds this HURTS: vector similarity can't carry
                  structured browse intent — reproduced here)
  * llm         — guided descent: child scores combine embedding similarity
                  with structured temporal intent (before/after/first/when +
                  anchor matching), the deterministic stand-in for LLM branch
                  selection (DESIGN.md §7)
  * llm+planner — llm browse + per-tree subqueries from root summaries
                  (anchor terms weighted, tree time-range aware)

The answerer is SHARED across all memory systems benchmarked (baselines
included): given retrieved canonical facts it applies query semantics
(current/before/when/first). Accuracy therefore measures retrieval quality —
the paper's framing.
"""
from __future__ import annotations

import re
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.config import MemForestConfig
from repro.core.forest import Forest
from repro.core.memtree import TreeArena
from repro.core.types import CanonicalFact, Query, QueryResult
from repro.data import templates as T
from repro.kernels import ops

_BEFORE_RE = re.compile(r"before (?:moving to |becoming |project )?([A-Za-z ]+?)\?")
_WHEN_RE = re.compile(r"^When did")
_FIRST_RE = re.compile(r"first")
_NOW_RE = re.compile(r"now\?$")


_STOPWORDS = frozenset(
    "what where when did does do is was the a an to of in on as now first "
    "before after moving become becoming switch switched start started who "
    "which place over since".split()
)


def _content_words(text: str):
    return {w for w in re.findall(r"[a-z]+", text.lower()) if w not in _STOPWORDS}


class TemporalIntent:
    __slots__ = ("relation", "anchor", "attribute")

    def __init__(self, relation: str, anchor: Optional[str], attribute: str = ""):
        self.relation = relation      # before | when | first | current | none
        self.anchor = anchor
        self.attribute = attribute    # inferred topical family (may be "")

    @staticmethod
    def parse(text: str) -> "TemporalIntent":
        attr = T.infer_attribute(text)
        m = _BEFORE_RE.search(text)
        if m:
            return TemporalIntent("before", m.group(1).strip(), attr)
        if _WHEN_RE.search(text):
            m2 = re.search(r"(?:move to|become|switch to project|preferring) ([A-Za-z ]+?)\?", text)
            return TemporalIntent("when", m2.group(1).strip() if m2 else None, attr)
        if _FIRST_RE.search(text):
            return TemporalIntent("first", None, attr)
        if _NOW_RE.search(text):
            return TemporalIntent("current", None, attr)
        return TemporalIntent("none", None, attr)

    def matches_attr(self, text: str) -> bool:
        if not self.attribute:
            return False
        kws = T.ATTR_KEYWORDS[self.attribute]
        return bool(set(re.findall(r"[a-z]+", text.lower())) & kws)


class Retriever:
    def __init__(self, forest: Forest, encoder, config: MemForestConfig):
        self.forest = forest
        self.encoder = encoder
        self.config = config

    # ------------------------------------------------------------------
    def retrieve(self, text: str, mode: Optional[str] = None,
                 final_topk: Optional[int] = None) -> Tuple[List[CanonicalFact], List[str], Dict]:
        """Returns (facts, evidence_texts, stats)."""
        cfg = self.config
        mode = mode or cfg.browse_mode
        topk = final_topk or cfg.final_topk
        t0 = time.perf_counter()
        calls0 = self.encoder.stats.calls

        q_emb = self.encoder.encode([text])[0]
        intent = TemporalIntent.parse(text)

        if mode == "flat":
            facts = self._flat_topk(q_emb, topk)
            return facts, [f.text for f in facts], self._stats(t0, calls0)

        trees = self._forest_recall(q_emb)
        if mode == "root-only":
            ev = [t.text[t.root][:200] if t.root >= 0 else "" for t in trees]
            facts = self._facts_from_summaries(trees, topk)
            return facts, ev, self._stats(t0, calls0)

        leaves: List[Tuple[TreeArena, int, float]] = []
        for tree in trees:
            browse_q = q_emb
            browse_intent = intent
            if mode.endswith("+planner"):
                browse_q, browse_intent = self._plan(tree, text, q_emb, intent, mode)
            use_intent = mode.startswith("llm")
            leaves.extend(
                self._browse(tree, browse_q,
                             browse_intent if use_intent else None,
                             text if use_intent else None)
            )

        facts, ev = self._resolve(leaves, q_emb, intent, topk, use_intent=mode.startswith("llm"))
        return facts, ev, self._stats(t0, calls0)

    def _stats(self, t0, calls0) -> Dict:
        return {
            "retrieval_s": time.perf_counter() - t0,
            "encoder_calls": self.encoder.stats.calls - calls0,
        }

    # ------------------------------------------------------------------
    def retrieve_batch(self, texts: List[str], mode: Optional[str] = None,
                       final_topk: Optional[int] = None):
        """Batched retrieval for serving throughput: ONE encoder forward and
        ONE fused topk_sim over the fact/root indexes for all queries (the
        kernel's Q dimension), then per-query browse. Returns a list of
        (facts, evidence, stats) like retrieve()."""
        cfg = self.config
        mode = mode or cfg.browse_mode
        topk = final_topk or cfg.final_topk
        t0 = time.perf_counter()
        calls0 = self.encoder.stats.calls

        q_embs = self.encoder.encode(texts)              # one batch
        mat, n_facts = self.forest.fact_index()
        roots, n_trees, order = self.forest.root_index()

        flat_idx = None
        if n_facts:
            _, flat_idx = ops.topk_sim(
                jnp.asarray(q_embs), jnp.asarray(mat),
                min(max(topk, cfg.fact_recall_topk), n_facts),
                num_valid=n_facts, impl=self.forest.kernel_impl,
            )
            flat_idx = np.asarray(flat_idx)
        root_idx = None
        if n_trees:
            _, root_idx = ops.topk_sim(
                jnp.asarray(q_embs), jnp.asarray(roots),
                min(cfg.forest_recall_topk * 3, n_trees),
                num_valid=n_trees, impl=self.forest.kernel_impl,
            )
            root_idx = np.asarray(root_idx)

        out = []
        for qi, text in enumerate(texts):
            q_emb = q_embs[qi]
            flat = []
            if flat_idx is not None:
                for i in flat_idx[qi]:
                    if i >= 0 and self.forest.fact_alive[int(i)]:
                        flat.append(self.forest.facts[int(i)])
            if mode == "flat":
                out.append((flat[:topk], [f.text for f in flat[:topk]],
                            self._stats(t0, calls0)))
                continue
            intent = TemporalIntent.parse(text)
            trees = self._recall_from_precomputed(
                q_emb, flat, root_idx[qi] if root_idx is not None else None, order)
            leaves: List[Tuple[TreeArena, int, float]] = []
            for tree in trees:
                browse_q, browse_intent = q_emb, intent
                if mode.endswith("+planner"):
                    browse_q, browse_intent = self._plan(tree, text, q_emb, intent, mode)
                use_intent = mode.startswith("llm")
                leaves.extend(self._browse(
                    tree, browse_q, browse_intent if use_intent else None,
                    text if use_intent else None))
            facts, ev = self._resolve(leaves, q_emb, intent, topk,
                                      use_intent=mode.startswith("llm"))
            out.append((facts, ev, self._stats(t0, calls0)))
        return out

    def _recall_from_precomputed(self, q_emb, flat_facts, root_row, order):
        cfg = self.config
        allowed = set(cfg.tree_families)
        scores: Dict[str, float] = {}
        if root_row is not None:
            for i in root_row:
                if i >= 0:
                    key = order[int(i)]
                    roots_mat, _, _ = self.forest.root_index()
                    scores[key] = float(roots_mat[self.forest.trees[key].tree_id] @ q_emb)
        for f in flat_facts[: cfg.fact_recall_topk]:
            sim = float(f.emb @ q_emb)
            for scope_key, _leaf in self.forest.placement.get(("fact", f.fact_id), []):
                scores[scope_key] = max(scores.get(scope_key, -1e9), 0.95 * sim)
            if "session" in allowed:
                for sid, _ in f.sources[:2]:
                    key = f"session:{sid}"
                    if key in self.forest.trees:
                        scores[key] = max(scores.get(key, -1e9), 0.9 * sim)
        scores = {k: v for k, v in scores.items()
                  if self.forest.trees[k].kind in allowed}
        ranked = sorted(scores.items(), key=lambda kv: -kv[1])[: cfg.forest_recall_topk]
        return [self.forest.trees[k] for k, _ in ranked
                if self.forest.trees[k].root >= 0]

    # ------------------------------------------------------------------
    def _flat_topk(self, q_emb: np.ndarray, k: int) -> List[CanonicalFact]:
        mat, n = self.forest.fact_index()
        if n == 0:
            return []
        vals, idx = ops.topk_sim(
            jnp.asarray(q_emb[None]), jnp.asarray(mat), min(k, n),
            num_valid=n, impl=self.forest.kernel_impl,
        )
        out = []
        for i in np.asarray(idx[0]):
            if i >= 0 and self.forest.fact_alive[int(i)]:
                out.append(self.forest.facts[int(i)])
        return out

    def _forest_recall(self, q_emb: np.ndarray) -> List[TreeArena]:
        cfg = self.config
        roots, n_trees, order = self.forest.root_index()
        allowed = set(cfg.tree_families)
        scores: Dict[str, float] = {}
        if n_trees:
            k = min(cfg.forest_recall_topk * 3, n_trees)
            vals, idx = ops.topk_sim(
                jnp.asarray(q_emb[None]), jnp.asarray(roots), k,
                num_valid=n_trees, impl=self.forest.kernel_impl,
            )
            for v, i in zip(np.asarray(vals[0]), np.asarray(idx[0])):
                if i >= 0:
                    scores[order[int(i)]] = max(scores.get(order[int(i)], -1e9), float(v))
        # fact -> tree recall
        for f in self._flat_topk(q_emb, cfg.fact_recall_topk):
            sim = float(f.emb @ q_emb)
            for scope_key, _leaf in self.forest.placement.get(("fact", f.fact_id), []):
                s = 0.95 * sim
                scores[scope_key] = max(scores.get(scope_key, -1e9), s)
        # fact -> source-session recall (session trees host cells; the facts'
        # source refs map them back — keeps the fallback channel recallable)
        if "session" in allowed:
            for f in self._flat_topk(q_emb, cfg.fact_recall_topk):
                for sid, _ in f.sources[:2]:
                    key = f"session:{sid}"
                    if key in self.forest.trees:
                        scores[key] = max(scores.get(key, -1e9),
                                          0.9 * float(f.emb @ q_emb))
        # family filter BEFORE ranking (tree-family ablation must not starve)
        scores = {
            k: v for k, v in scores.items()
            if self.forest.trees[k].kind in allowed
        }
        ranked = sorted(scores.items(), key=lambda kv: -kv[1])[: cfg.forest_recall_topk]
        out = []
        for key, _ in ranked:
            t = self.forest.trees.get(key)
            if t is not None and t.root >= 0:
                out.append(t)
        return out

    # ------------------------------------------------------------------
    def _plan(self, tree: TreeArena, text: str, q_emb: np.ndarray,
              intent: TemporalIntent, mode: str):
        """Planner: one call per tree creating a targeted subquery. For llm
        browse it sharpens the intent with the anchor term; for emb browse the
        rewrite is reduced to a vector mix (which is why emb+planner loses
        signal — paper §6.2)."""
        root_summary = tree.text[tree.root] if tree.root >= 0 else ""
        sub = f"{text} [tree] {root_summary[:120]}"
        sub_emb = self.encoder.encode([sub])[0]     # planner cost: 1 call/tree
        if mode.startswith("emb"):
            mix = 0.5 * q_emb + 0.5 * sub_emb
            mix /= (np.linalg.norm(mix) + 1e-6)
            return mix, intent
        return q_emb, intent                        # llm: keep query, sharpen intent

    # ------------------------------------------------------------------
    def _browse(self, tree: TreeArena, q_emb: np.ndarray,
                intent: Optional[TemporalIntent],
                q_text: Optional[str] = None) -> List[Tuple[TreeArena, int, float]]:
        """Coarse-to-fine descent. Returns (tree, leaf, score) candidates."""
        if tree.root < 0:
            return []
        q_words = _content_words(q_text) if q_text else set()
        beam = [(tree.root, 1.0)]
        budget = self.config.browse_beam
        collected: Dict[int, float] = {}
        while beam:
            next_beam: List[Tuple[int, float]] = []
            for node, _ in beam:
                if tree.level[node] == 0:
                    s = float(tree.emb[node] @ q_emb)
                    if intent is not None:
                        s += self._leaf_bonus(tree, node, intent, q_words)
                    collected[node] = max(collected.get(node, -1e9), s)
                    continue
                kids = tree.children[node]
                sims = np.asarray([float(tree.emb[c] @ q_emb) for c in kids])
                if intent is not None:
                    sims = sims + self._intent_bonus(tree, kids, intent, q_words)
                top = np.argsort(-sims)[:budget]
                next_beam.extend((kids[i], float(sims[i])) for i in top)
            agg: Dict[int, float] = {}
            for n, s in next_beam:
                agg[n] = max(agg.get(n, -1e9), s)
            beam = sorted(agg.items(), key=lambda kv: -kv[1])[: max(budget * 2, 6)]
        leaves = sorted(collected.items(), key=lambda kv: -kv[1])[:16]
        out = [(tree, n, s) for n, s in leaves]
        if intent is not None:
            out.extend(self._temporal_navigate(tree, intent, q_words))
        return out

    def _intent_bonus(self, tree: TreeArena, kids: Sequence[int],
                      intent: TemporalIntent, q_words) -> np.ndarray:
        """The 'LLM reads child summaries' advantage: anchor-term + content-
        word matching and temporal-relation preferences that a bare vector
        score cannot carry."""
        bonus = np.zeros(len(kids), np.float32)
        for i, c in enumerate(kids):
            txt = tree.text[c].lower()
            if intent.anchor and intent.anchor.lower() in txt:
                bonus[i] += 0.30
            if q_words:
                overlap = len(q_words & _content_words(txt))
                bonus[i] += min(0.05 * overlap, 0.20)
            if intent.relation == "first" and i == 0:
                bonus[i] += 0.15      # earliest interval
            if intent.relation == "current" and i == len(kids) - 1:
                bonus[i] += 0.15      # latest interval
        return bonus

    def _leaf_bonus(self, tree: TreeArena, leaf: int,
                    intent: TemporalIntent, q_words) -> float:
        txt = tree.text[leaf].lower()
        b = 0.0
        if intent.anchor and intent.anchor.lower() in txt:
            b += 0.30
        if q_words:
            b += min(0.05 * len(q_words & _content_words(txt)), 0.20)
        return b

    def _temporal_navigate(self, tree: TreeArena, intent: TemporalIntent,
                           q_words) -> List[Tuple[TreeArena, int, float]]:
        """Explicit temporal navigation over the leaf order — what MemTree
        makes possible and flat stores cannot do (paper §4.3):
          * before/when: the anchor transition leaf + its predecessor,
          * current: the LAST topically-matching leaf,
          * first: the FIRST topically-matching leaf."""
        leaves = tree.leaves_in_order()
        out: List[Tuple[TreeArena, int, float]] = []
        if intent.relation in ("before", "when") and intent.anchor:
            for j, leaf in enumerate(leaves):
                if intent.anchor.lower() in tree.text[leaf].lower():
                    out.append((tree, leaf, 1.0))
                    if j > 0:
                        out.append((tree, leaves[j - 1], 0.99))
                    break
        elif intent.relation == "current":
            for leaf in reversed(leaves):
                if intent.matches_attr(tree.text[leaf]) or (
                    q_words and len(q_words & _content_words(tree.text[leaf])) >= 2
                ):
                    out.append((tree, leaf, 1.0))
                    break
        elif intent.relation == "first":
            for leaf in leaves:
                if intent.matches_attr(tree.text[leaf]) or (
                    q_words and len(q_words & _content_words(tree.text[leaf])) >= 2
                ):
                    out.append((tree, leaf, 1.0))
                    break
        return out

    # ------------------------------------------------------------------
    def _resolve(self, leaves, q_emb, intent, topk, *, use_intent: bool):
        seen = set()
        scored: List[Tuple[float, CanonicalFact, str]] = []
        for tree, leaf, score in leaves:
            pay = tree.payload[leaf]
            if pay is None or not tree.alive[leaf]:
                continue
            if pay >= 0:  # fact
                f = self.forest.facts[pay]
                if not self.forest.fact_alive[f.fact_id] or ("f", pay) in seen:
                    continue
                seen.add(("f", pay))
                # navigation hits (score ~1.0) must survive the rerank: they
                # are the LLM browser's explicit selections
                s = float(f.emb @ q_emb) + (score * (0.5 if use_intent else 0.1))
                if use_intent and intent:
                    if intent.anchor and intent.anchor.lower() in f.text.lower():
                        s += 0.3
                    if intent.matches_attr(f.text):
                        s += 0.15
                scored.append((s, f, f.text))
            else:        # dialogue cell — re-extract facts (fallback channel)
                cell = self.forest.cells[-pay - 1]
                if ("c", cell.cell_id) in seen:
                    continue
                seen.add(("c", cell.cell_id))
                for cand in T.parse_statement(cell.text, (cell.session_id, cell.chunk_idx)):
                    ftmp = CanonicalFact(
                        fact_id=-1, text=cand.text, subject=cand.subject,
                        attribute=cand.attribute, value=cand.value, ts=cand.ts,
                        prev_value=cand.prev_value, sources=[cand.source],
                        emb=q_emb * 0,
                    )
                    scored.append((score * 0.5, ftmp, cell.text[:160]))
        scored.sort(key=lambda x: -x[0])
        top = scored[:topk]
        return [f for _, f, _ in top], [e for _, _, e in top]

    def _facts_from_summaries(self, trees: List[TreeArena], topk: int) -> List[CanonicalFact]:
        """root-only mode: parse what survives in root summaries (compressed,
        lossy — the paper's point)."""
        out = []
        for t in trees:
            if t.root < 0:
                continue
            for cand in T.parse_statement(t.text[t.root], ("root", 0)):
                out.append(CanonicalFact(
                    fact_id=-1, text=cand.text, subject=cand.subject,
                    attribute=cand.attribute, value=cand.value, ts=cand.ts,
                    prev_value=cand.prev_value, sources=[cand.source], emb=None,
                ))
        return out[:topk]


# ---------------------------------------------------------------------------
# shared answerer (all systems)
# ---------------------------------------------------------------------------
def answer_query(query: Query, facts: List[CanonicalFact]) -> str:
    """Apply query semantics over the retrieved fact set."""
    rel = [
        f for f in facts
        if f.subject.lower() == query.subject.lower()
        and f.attribute == query.attribute
    ]
    if not rel:
        return ""
    rel.sort(key=lambda f: f.ts)
    if query.qtype == "current":
        return rel[-1].value
    if query.qtype == "historical":
        anchor = (query.anchor_value or "").lower()
        for f in rel:
            if f.value.lower() == anchor and f.prev_value:
                return f.prev_value
        before = [f for f in rel if f.value.lower() != anchor]
        anchor_ts = next((f.ts for f in rel if f.value.lower() == anchor), None)
        if anchor_ts is not None:
            before = [f for f in before if f.ts < anchor_ts]
        return before[-1].value if before else ""
    if query.qtype == "transition_time":
        anchor = (query.anchor_value or "").lower()
        for f in rel:
            if f.value.lower() == anchor:
                return T.ts_to_date(f.ts)
        return ""
    if query.qtype in ("multi_session", "single_session"):
        return rel[0].value
    return rel[-1].value
