"""Async maintenance plane: summary refresh, compaction, and merge work off
the serve loop (ROADMAP: "maintenance ... cannot run inline with serving").

The serve path only *marks* work — ingest leaves trees dirty
(``defer_flush=True``), deletions tombstone leaves, merge requests queue —
and the :class:`MaintenancePlane` drains it in bounded slices:

  * **cooperative mode** (default): :meth:`run_some` executes up to
    ``budget`` work units; :class:`repro.serving.engine.ServeEngine` calls
    it once per decode step, so refresh kernels overlap the decode cadence
    instead of blocking an ingest or query drain.
  * **background mode**: :meth:`start_background` runs the same drain on a
    worker thread under ``self.lock`` — the lock serializes maintenance
    against serve-side forest access (the Forest itself is not
    thread-safe).

One work unit = one queued merge, or one tree compaction, or one bounded
flush slice (``flush_trees_per_unit`` dirty trees through
``Forest.flush(only=...)``). Chunked flushing is state-equivalent to one
full flush because dirty paths never cross trees.

Correctness under laziness is unchanged: a reader that arrives before the
plane catches up pays the remaining flush itself (read-triggered refresh in
``MemForestSystem.query``), so answers never see stale mandatory state.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core import maintenance
from repro.core.forest import Forest
from repro.obs import Observability, get_obs


class MaintenancePlane:
    def __init__(self, forest: Forest, *, flush_trees_per_unit: int = 4,
                 compact_min_dead_fraction: float = 0.3, durable=None,
                 residency=None, obs: Optional[Observability] = None):
        """``durable``: a :class:`repro.core.journal.DurableMemForest`
        wrapping the same forest. When given, compactions run through its
        journaled ``compact_tree`` op — compaction rewrites persistent state
        (tree arena + placement rows), so on a durable store it must be
        journaled for crash recovery to reproduce the pre-crash digest.

        ``residency``: a :class:`repro.core.residency.ResidencyManager`.
        When given, one over-budget tenant demotion counts as a work unit
        (lowest priority — after merges/compaction/flush), so background-
        thread deployments evict continuously off the serve thread. The
        manager has its own lock, so cross-tenant demotion is safe from the
        worker even though this plane's forest lock guards only one
        tenant."""
        self.forest = forest
        self.durable = durable
        self.residency = residency
        self.flush_trees_per_unit = flush_trees_per_unit
        self.compact_min_dead_fraction = compact_min_dead_fraction
        self.lock = threading.RLock()
        self._merge_q: Deque[Tuple[Forest, Optional[str]]] = deque()
        self._compact_q: Deque[str] = deque()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # counters live in the registry (maintenance/* namespace); the
        # legacy attribute names read back through properties below and
        # metrics() reports straight from the registry
        self.obs = get_obs(obs)
        reg = self.obs.registry
        self._m_units = reg.counter("maintenance/units_run")
        self._m_flushed = reg.counter("maintenance/trees_flushed")
        self._m_merges = reg.counter("maintenance/merges_done")
        self._m_compactions = reg.counter("maintenance/compactions_done")
        self._m_reclaimed = reg.counter("maintenance/slots_reclaimed")
        self._m_demotions = reg.counter("maintenance/demotions_done")

    # ------------------------------------------------------------------
    # registry-backed legacy counters (attribute back-compat)
    # ------------------------------------------------------------------
    @property
    def units_run(self) -> int:
        return self._m_units.value

    @property
    def trees_flushed(self) -> int:
        return self._m_flushed.value

    @property
    def merges_done(self) -> int:
        return self._m_merges.value

    @property
    def compactions_done(self) -> int:
        return self._m_compactions.value

    @property
    def slots_reclaimed(self) -> int:
        return self._m_reclaimed.value

    @property
    def demotions_done(self) -> int:
        return self._m_demotions.value

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    def schedule_merge(self, src, *, idempotency_key: Optional[str] = None) -> None:
        """Queue a migration merge (src: Forest or MemForestSystem)."""
        with self.lock:
            self._merge_q.append((getattr(src, "forest", src), idempotency_key))

    def schedule_compaction(self, scope_key: Optional[str] = None) -> int:
        """Queue one tree — or scan the forest for every tombstone-heavy
        tree — for compaction. Returns how many were queued."""
        with self.lock:
            if scope_key is not None:
                self._compact_q.append(scope_key)
                return 1
            cands = maintenance.compaction_candidates(
                self.forest, min_dead_fraction=self.compact_min_dead_fraction)
            queued = [k for k in cands if k not in self._compact_q]
            self._compact_q.extend(queued)
            return len(queued)

    def pending(self) -> int:
        """Outstanding work units (approximate for flush slices)."""
        with self.lock:
            flush_units = -(-len(self.forest.dirty_trees) //
                            max(self.flush_trees_per_unit, 1))
            resid_units = self.residency.over_budget() \
                if self.residency is not None else 0
            return len(self._merge_q) + len(self._compact_q) + flush_units \
                + resid_units

    # ------------------------------------------------------------------
    # draining
    # ------------------------------------------------------------------
    def _run_one(self) -> bool:
        """One work unit; returns False when there was nothing to do.
        Priority: merges (they add dirty trees), then compactions, then a
        flush slice — so structural work lands before its summaries
        regenerate."""
        if self._merge_q:
            src, key = self._merge_q.popleft()
            with self.obs.span("maintenance.merge"):
                if self.durable is not None:
                    # ride the journal: a crash mid-merge must replay it
                    self.durable.merge_from(src, idempotency_key=key,
                                            flush=False)
                else:
                    # non-durable deployment — there is no journal to ride
                    maintenance.migrate_merge(self.forest, src,  # memlint: ignore[journaled-mutation]
                                              idempotency_key=key, flush=False)
            self._m_merges.inc()
            return True
        if self._compact_q:
            scope = self._compact_q.popleft()
            if scope in self.forest.trees:
                with self.obs.span("maintenance.compaction", scope=scope):
                    if self.durable is not None:
                        stats = self.durable.compact_tree(scope)
                    else:
                        # non-durable deployment — no journal to ride
                        stats = maintenance.compact_tree(self.forest, scope)  # memlint: ignore[journaled-mutation]
                self._m_reclaimed.inc(stats["slots_reclaimed"])
                self._m_compactions.inc()
            return True
        if self.forest.dirty_trees:
            chunk = set(sorted(self.forest.dirty_trees)
                        [: self.flush_trees_per_unit])
            with self.obs.span("maintenance.flush_slice", trees=len(chunk)):
                self.forest.flush(only=chunk)
            self._m_flushed.inc(len(chunk))
            return True
        if self.residency is not None \
                and self.residency.enforce_budget(1):
            self._m_demotions.inc()
            return True
        return False

    def run_some(self, budget: int = 1) -> Dict[str, int]:
        """Drain up to ``budget`` work units. Safe from any thread (takes
        the plane lock). Returns {"units": executed, "pending": left}."""
        done = 0
        with self.lock:
            for _ in range(max(budget, 0)):
                if not self._run_one():
                    break
                done += 1
                self._m_units.inc()
            return {"units": done, "pending": self.pending()}

    def drain(self, max_units: int = 100000) -> int:
        """Run until no work remains; returns units executed."""
        total = 0
        while max_units > 0:
            step = self.run_some(min(max_units, 64))
            total += step["units"]
            max_units -= max(step["units"], 1)
            if step["units"] == 0:
                break
        return total

    # ------------------------------------------------------------------
    # background worker mode
    # ------------------------------------------------------------------
    def start_background(self, *, interval_s: float = 0.002,
                         budget_per_wake: int = 4) -> None:
        """Move draining to a worker thread. Serve-side forest access must
        then also hold ``self.lock`` (ServeEngine does when built with a
        plane)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if self.run_some(budget_per_wake)["units"] == 0:
                    time.sleep(interval_s)

        self._thread = threading.Thread(target=loop, name="memforest-maint",
                                        daemon=True)
        self._thread.start()

    def stop_background(self, *, drain_first: bool = True) -> None:
        if self._thread is None:
            return
        if drain_first:
            self.drain()
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None

    def metrics(self) -> Dict[str, int]:
        """Legacy keys, reported through the registry (the counters behind
        the properties ARE registry counters — see __init__)."""
        return {
            "maintenance_units": self._m_units.value,
            "maintenance_trees_flushed": self._m_flushed.value,
            "maintenance_merges": self._m_merges.value,
            "maintenance_compactions": self._m_compactions.value,
            "maintenance_slots_reclaimed": self._m_reclaimed.value,
            "maintenance_demotions": self._m_demotions.value,
            "maintenance_pending": self.pending(),
        }
