"""Deterministic hash tokenizer (no external vocab files)."""
from __future__ import annotations

import re
import zlib
from typing import List

_WORD_RE = re.compile(r"[a-zA-Z0-9]+|[^\sa-zA-Z0-9]")


class HashTokenizer:
    def __init__(self, vocab_size: int = 32000):
        self.vocab_size = vocab_size
        self.bos_id = 1
        self.eos_id = 2

    def encode(self, text: str, add_bos: bool = False) -> List[int]:
        toks = _WORD_RE.findall(text.lower())
        ids = [3 + (zlib.crc32(t.encode()) % (self.vocab_size - 3)) for t in toks]
        return ([self.bos_id] + ids) if add_bos else ids

    def decode(self, ids: List[int]) -> str:  # lossy (hash) — debugging only
        return " ".join(f"<{i}>" for i in ids)
