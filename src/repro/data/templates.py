"""Shared statement grammar for the synthetic temporal workload.

The generator renders state transitions into natural-ish sentences; the
extractor parses the same grammar (the stand-in for LLM language competence —
see DESIGN.md §3). Timestamps are "months since Jan 2020" floats; dates
render as "March 2023" style strings.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.core.types import RawCandidate

MONTHS = [
    "January", "February", "March", "April", "May", "June",
    "July", "August", "September", "October", "November", "December",
]


def ts_to_date(ts: float) -> str:
    m = int(ts)
    return f"{MONTHS[m % 12]} {2020 + m // 12}"


def date_to_ts(month: str, year: str) -> float:
    return float((int(year) - 2020) * 12 + MONTHS.index(month))


# attribute grammar: transition + state templates and their parse regexes
ATTRS: Dict[str, Dict[str, str]] = {
    "residence": {
        "transition": "{subj} moved from {old} to {new} in {date}.",
        "state": "{subj} lives in {val} as of {date}.",
        "q_current": "Where does {subj} live now?",
        "q_before": "Where did {subj} live before moving to {anchor}?",
        "q_when": "When did {subj} move to {anchor}?",
        "q_first": "What was the first place {subj} lived in?",
    },
    "job": {
        "transition": "{subj} changed jobs from {old} to {new} in {date}.",
        "state": "{subj} works as a {val} as of {date}.",
        "q_current": "What does {subj} work as now?",
        "q_before": "What job did {subj} have before becoming {anchor}?",
        "q_when": "When did {subj} become {anchor}?",
        "q_first": "What was the first job {subj} had?",
    },
    "project": {
        "transition": "{subj} switched project {old} to project {new} in {date}.",
        "state": "{subj} is working on project {val} as of {date}.",
        "q_current": "Which project is {subj} working on now?",
        "q_before": "Which project did {subj} work on before project {anchor}?",
        "q_when": "When did {subj} switch to project {anchor}?",
        "q_first": "What was the first project {subj} worked on?",
    },
    "preference": {
        "transition": "{subj} now prefers {new} over {old} since {date}.",
        "state": "{subj}'s favorite thing is {val} as of {date}.",
        "q_current": "What does {subj} prefer now?",
        "q_before": "What did {subj} prefer before {anchor}?",
        "q_when": "When did {subj} start preferring {anchor}?",
        "q_first": "What did {subj} prefer first?",
    },
}

_DATE = r"(January|February|March|April|May|June|July|August|September|October|November|December) (\d{4})"

_PARSERS: List[Tuple[str, re.Pattern]] = []
for attr, g in ATTRS.items():
    _PARSERS.append((
        attr,
        re.compile({
            "residence": rf"(?P<subj>[A-Z][a-z]+) moved from (?P<old>[A-Z][A-Za-z ]+?) to (?P<new>[A-Z][A-Za-z ]+?) in {_DATE}\.",
            "job": rf"(?P<subj>[A-Z][a-z]+) changed jobs from (?P<old>[a-z ]+?) to (?P<new>[a-z ]+?) in {_DATE}\.",
            "project": rf"(?P<subj>[A-Z][a-z]+) switched project (?P<old>[A-Za-z]+?) to project (?P<new>[A-Za-z]+?) in {_DATE}\.",
            "preference": rf"(?P<subj>[A-Z][a-z]+) now prefers (?P<new>[a-z ]+?) over (?P<old>[a-z ]+?) since {_DATE}\.",
        }[attr]),
    ))
    _PARSERS.append((
        attr + "::state",
        re.compile({
            "residence": rf"(?P<subj>[A-Z][a-z]+) lives in (?P<val>[A-Z][A-Za-z ]+?) as of {_DATE}\.",
            "job": rf"(?P<subj>[A-Z][a-z]+) works as a (?P<val>[a-z ]+?) as of {_DATE}\.",
            "project": rf"(?P<subj>[A-Z][a-z]+) is working on project (?P<val>[A-Za-z]+?) as of {_DATE}\.",
            "preference": rf"(?P<subj>[A-Z][a-z]+)'s favorite thing is (?P<val>[a-z ]+?) as of {_DATE}\.",
        }[attr]),
    ))


def render_transition(attr: str, subj: str, old: str, new: str, ts: float) -> str:
    return ATTRS[attr]["transition"].format(subj=subj, old=old, new=new, date=ts_to_date(ts))


def render_state(attr: str, subj: str, val: str, ts: float) -> str:
    return ATTRS[attr]["state"].format(subj=subj, val=val, date=ts_to_date(ts))


def parse_statement(text: str, source: Tuple[str, int]) -> List[RawCandidate]:
    """Extract raw fact candidates from one sentence (LLM stand-in)."""
    out: List[RawCandidate] = []
    for name, pat in _PARSERS:
        for m in pat.finditer(text):
            g = m.groupdict()
            date_groups = m.groups()[-2:]
            ts = date_to_ts(date_groups[0], date_groups[1])
            attr = name.split("::")[0]
            if "val" in g and g.get("val"):
                out.append(RawCandidate(
                    text=m.group(0), subject=g["subj"], attribute=attr,
                    value=g["val"].strip(), ts=ts, prev_value=None, source=source,
                ))
            else:
                out.append(RawCandidate(
                    text=m.group(0), subject=g["subj"], attribute=attr,
                    value=g["new"].strip(), ts=ts,
                    prev_value=g["old"].strip(), source=source,
                ))
    return out


# attribute keyword families (what an LLM knows about paraphrase): used by
# the guided-browse intent layer to recognize which attribute a query or an
# interval summary is about.
ATTR_KEYWORDS = {
    "residence": {"live", "lives", "lived", "moved", "place", "city", "residence"},
    "job": {"work", "works", "working", "job", "jobs", "became", "become", "career"},
    "project": {"project", "projects", "switched"},
    "preference": {"prefer", "prefers", "preferred", "favorite", "preferring"},
}


def infer_attribute(text: str) -> str:
    low = set(re.findall(r"[a-z]+", text.lower()))
    best, score = "", 0
    for attr, kws in ATTR_KEYWORDS.items():
        s = len(low & kws)
        if s > score:
            best, score = attr, s
    return best


CHITCHAT = [
    "The weather has been quite nice lately.",
    "Did you watch the game last weekend?",
    "I should really get more sleep these days.",
    "Traffic was terrible this morning.",
    "That restaurant downtown finally reopened.",
    "My phone battery keeps dying too fast.",
    "The new season of that show just dropped.",
    "I keep forgetting to water the plants.",
    "Someone recommended a great podcast to me.",
    "The coffee machine at work broke again.",
]

ASSISTANT_ACKS = [
    "That's great to hear, thanks for sharing.",
    "Noted — I'll remember that.",
    "Interesting, tell me more sometime.",
    "Got it, thanks for the update.",
    "Understood, I've made a note of that.",
]
