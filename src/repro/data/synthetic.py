"""Synthetic temporal-memory workload generator.

Produces the LongMemEval-S-style evaluation instances this repo benchmarks
on: per-entity state *trajectories* (residence/job/project/preference
transitions over months), rendered into multi-session dialogues with
distractor chitchat, plus queries with exact gold answers across the
categories the paper analyzes:

  * current         — "Where does Bob live now?"            (knowledge-update)
  * historical      — "Where did Bob live before Miami?"    (temporal-reasoning)
  * transition_time — "When did Bob move to Miami?"         (temporal-reasoning)
  * multi_session   — "What was the first place Bob lived?" (multi-session)
  * single_session  — preference stated once among distractors

Everything is seeded and deterministic.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.types import Query, Session, Turn
from repro.data import templates as T

NAMES = [
    "Bob", "Alice", "Carol", "David", "Erin", "Frank", "Grace", "Henry",
    "Irene", "Jack", "Karen", "Liam", "Mona", "Nina", "Oscar", "Paula",
]
CITIES = [
    "Boston", "Davis", "Miami", "Seattle", "Austin", "Denver", "Chicago",
    "Portland", "Atlanta", "Phoenix", "Madison", "Raleigh",
]
JOBS = [
    "teacher", "nurse", "barista", "carpenter", "designer", "writer",
    "chef", "gardener", "translator", "photographer",
]
PROJECTS = ["Apollo", "Borealis", "Cascade", "Dynamo", "Ember", "Falcon", "Gyro"]
PREFS = ["green tea", "black coffee", "jazz music", "rock climbing", "oil painting",
         "chess", "cycling", "pottery"]

VALUE_POOLS = {
    "residence": CITIES,
    "job": JOBS,
    "project": PROJECTS,
    "preference": PREFS,
}


@dataclass
class Trajectory:
    subject: str
    attribute: str
    events: List[Tuple[float, str]]  # (ts, value); first event = initial state

    def value_at(self, ts: float) -> Optional[str]:
        cur = None
        for t, v in self.events:
            if t <= ts:
                cur = v
        return cur


@dataclass
class Workload:
    sessions: List[Session]
    queries: List[Query]
    trajectories: List[Trajectory]
    gold_ranges: Dict[int, Tuple[str, str]] = field(default_factory=dict)
    # query idx -> (session_id containing the gold evidence, key span)


def make_workload(
    *,
    num_entities: int = 4,
    num_sessions: int = 12,
    transitions_per_entity: int = 3,
    distractor_turns: int = 6,
    num_queries: int = 40,
    seed: int = 0,
) -> Workload:
    rng = random.Random(seed)
    subjects = rng.sample(NAMES, num_entities)

    # --- build trajectories ------------------------------------------------
    trajectories: List[Trajectory] = []
    for subj in subjects:
        for attr, pool in VALUE_POOLS.items():
            if rng.random() < 0.35 and attr != "residence":
                continue  # not every entity has every attribute
            n_vals = min(1 + transitions_per_entity, len(pool))
            vals = rng.sample(pool, n_vals)
            t0 = rng.uniform(0, 12)
            gaps = [rng.uniform(3, 14) for _ in range(n_vals - 1)]
            events = [(t0, vals[0])]
            t = t0
            for v, g in zip(vals[1:], gaps):
                t += g
                events.append((t, v))
            trajectories.append(Trajectory(subj, attr, events))

    # --- schedule events into sessions --------------------------------------
    all_events: List[Tuple[float, Trajectory, int]] = []
    for tr in trajectories:
        for i, (ts, _) in enumerate(tr.events):
            all_events.append((ts, tr, i))
    all_events.sort(key=lambda x: x[0])

    t_min = all_events[0][0]
    t_max = all_events[-1][0] + 1
    bounds = [t_min + (t_max - t_min) * i / num_sessions for i in range(num_sessions + 1)]

    sessions: List[Session] = []
    event_session: Dict[Tuple[str, str, int], str] = {}
    for s in range(num_sessions):
        sid = f"s{s:03d}"
        lo, hi = bounds[s], bounds[s + 1]
        turns: List[Turn] = []
        ts_base = lo
        ev_here = [(ts, tr, i) for ts, tr, i in all_events if lo <= ts < hi]
        stmts: List[Tuple[float, str]] = []
        for ts, tr, i in ev_here:
            if i == 0:
                text = T.render_state(tr.attribute, tr.subject, tr.events[0][1], ts)
            else:
                text = T.render_transition(
                    tr.attribute, tr.subject, tr.events[i - 1][1], tr.events[i][1], ts
                )
            stmts.append((ts, text))
            event_session[(tr.subject, tr.attribute, i)] = sid
        # interleave with distractors
        n_turns = len(stmts) + distractor_turns
        stmt_iter = iter(sorted(stmts))
        positions = sorted(rng.sample(range(n_turns), len(stmts)))
        tid = 0
        for j in range(n_turns):
            if positions and j == positions[0]:
                positions.pop(0)
                ts, text = next(stmt_iter)
            else:
                ts, text = ts_base + j * 0.01, rng.choice(T.CHITCHAT)
            turns.append(Turn("user", text, ts, tid)); tid += 1
            turns.append(Turn("assistant", rng.choice(T.ASSISTANT_ACKS), ts + 0.001, tid)); tid += 1
        turns.sort(key=lambda t: t.ts)
        sessions.append(Session(sid, turns, ts=lo))

    # --- queries -------------------------------------------------------------
    queries: List[Query] = []
    gold_ranges: Dict[int, Tuple[str, str]] = {}
    multi = [tr for tr in trajectories if len(tr.events) >= 3]
    rng.shuffle(multi)
    qi = 0
    while len(queries) < num_queries and multi:
        tr = multi[qi % len(multi)]
        qi += 1
        g = T.ATTRS[tr.attribute]
        kind = ["current", "historical", "transition_time", "multi_session", "single_session"][
            len(queries) % 5
        ]
        last_ts, last_v = tr.events[-1]
        mid_idx = max(1, len(tr.events) - 1)
        if kind == "current":
            q = Query(g["q_current"].format(subj=tr.subject), "current",
                      tr.subject, tr.attribute, gold=last_v)
            gold_ranges[len(queries)] = (event_session[(tr.subject, tr.attribute, len(tr.events) - 1)], last_v)
        elif kind == "historical":
            anchor = tr.events[mid_idx][1]
            gold = tr.events[mid_idx - 1][1]
            q = Query(g["q_before"].format(subj=tr.subject, anchor=anchor), "historical",
                      tr.subject, tr.attribute, anchor_value=anchor, gold=gold)
            gold_ranges[len(queries)] = (event_session[(tr.subject, tr.attribute, mid_idx - 1)], gold)
        elif kind == "transition_time":
            anchor = tr.events[mid_idx][1]
            gold = T.ts_to_date(tr.events[mid_idx][0])
            q = Query(g["q_when"].format(subj=tr.subject, anchor=anchor), "transition_time",
                      tr.subject, tr.attribute, anchor_value=anchor, gold=gold)
            gold_ranges[len(queries)] = (event_session[(tr.subject, tr.attribute, mid_idx)], anchor)
        elif kind == "multi_session":
            gold = tr.events[0][1]
            q = Query(g["q_first"].format(subj=tr.subject), "multi_session",
                      tr.subject, tr.attribute, gold=gold)
            gold_ranges[len(queries)] = (event_session[(tr.subject, tr.attribute, 0)], gold)
        else:  # single_session: a preference-like lookup of the initial state
            gold = tr.events[0][1]
            q = Query(g["q_first"].format(subj=tr.subject), "single_session",
                      tr.subject, tr.attribute, gold=gold,
                      session_scope=event_session[(tr.subject, tr.attribute, 0)])
            gold_ranges[len(queries)] = (event_session[(tr.subject, tr.attribute, 0)], gold)
        queries.append(q)

    return Workload(sessions, queries, trajectories, gold_ranges)
