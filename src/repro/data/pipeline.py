"""Deterministic, shardable, checkpointable LM data pipeline.

Stateless addressing: `batch_at(step)` generates the batch for any step
directly from (seed, step, dp_rank), so checkpoint/restore only needs the
step counter — restart-consistency is exact (no replay, no cursors), which
is what the fault-tolerance path requires. Text corpora (the synthetic
session workload) are packed into fixed-length token sequences.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.data.tokenizer import HashTokenizer


class TokenPipeline:
    def __init__(self, *, vocab_size: int, seq_len: int, global_batch: int,
                 dp_rank: int = 0, dp_size: int = 1, seed: int = 0,
                 corpus: Optional[List[str]] = None):
        assert global_batch % dp_size == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.local_batch = global_batch // dp_size
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.seed = seed
        self._tokens: Optional[np.ndarray] = None
        if corpus:
            tok = HashTokenizer(vocab_size)
            ids: List[int] = []
            for doc in corpus:
                ids.extend(tok.encode(doc, add_bos=True))
                ids.append(tok.eos_id)
            self._tokens = np.asarray(ids, np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for `step` on this dp shard."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + self.dp_rank
        )
        B, S = self.local_batch, self.seq_len
        if self._tokens is not None and len(self._tokens) > S + 1:
            starts = rng.integers(0, len(self._tokens) - S - 1, size=B)
            tok = np.stack([self._tokens[s:s + S + 1] for s in starts])
        else:
            tok = rng.integers(3, self.vocab_size, size=(B, S + 1), dtype=np.int64)
        return {
            "tokens": tok[:, :-1].astype(np.int32),
            "labels": tok[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
