"""Sharded checkpointing with atomic two-phase commit (no orbax dependency).

Layout:
    <dir>/step_<N>.tmp/...   (write phase)
    <dir>/step_<N>/
        manifest.json        (tree structure, shapes, dtypes, metadata)
        shard_<i>.bin        (compressed msgpack of leaf buffers; zstd when
                             available, stdlib zlib otherwise — tagged)

Commit = fsync files -> atomic rename of the directory -> fsync the parent
directory (the rename itself must be durable) -> update LATEST file.
A crash mid-write leaves only a .tmp directory, which restore() ignores —
the previous checkpoint remains the recovery point (fault tolerance test
covers this). Multi-host: each process writes shard files for its addressable
shards; this container is single-process, so shard 0 carries everything, but
the manifest format carries (process, leaf, offset) so a resharded restore
can remap (see runtime/fault_tolerance.ElasticScaler).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import msgpack
import numpy as np

import jax

from repro import compression

_LEAVES_PER_SHARD = 64


# ---------------------------------------------------------------------------
# LATEST marker: crash-safe "current checkpoint" pointer, shared by the
# model-state checkpoints below and the memory-substrate snapshot+journal
# store (core/journal.py) — one commit protocol for both recovery points.
# ---------------------------------------------------------------------------
def fsync_dir(dir_path: str) -> None:
    """fsync a directory so a just-committed rename survives power loss —
    os.replace alone orders the data, not the directory entry. Best-effort
    on platforms whose directory fds reject fsync."""
    try:
        fd = os.open(dir_path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def write_latest(dir_path: str, name: str) -> None:
    """Atomically point <dir>/LATEST at `name` (fsync'd tmp + rename +
    directory fsync)."""
    tmp = os.path.join(dir_path, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(dir_path, "LATEST"))
    fsync_dir(dir_path)


def read_latest(dir_path: str) -> Optional[str]:
    """Name the LATEST marker points at, or None when absent."""
    marker = os.path.join(dir_path, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        return f.read().strip()


def _path_str(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save(ckpt_dir: str, step: int, state: Any, *, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(state)[0]
    treedef = jax.tree_util.tree_structure(state)

    manifest: Dict[str, Any] = {
        "step": step,
        "extra": extra or {},
        "process_index": jax.process_index(),
        "num_processes": jax.process_count(),
        "leaves": [],
    }
    shard_idx = 0
    buf: List[Tuple[str, bytes, str, List[int]]] = []

    def flush_shard():
        nonlocal shard_idx, buf
        if not buf:
            return
        payload = msgpack.packb(
            [(p, d, dt, sh) for p, d, dt, sh in buf], use_bin_type=True
        )
        fname = f"shard_{shard_idx:04d}.bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(compression.compress(payload))
            f.flush()
            os.fsync(f.fileno())
        for p, _d, dt, sh in buf:
            manifest["leaves"].append({"path": p, "shard": fname,
                                       "dtype": dt, "shape": sh})
        shard_idx += 1
        buf = []

    for keypath, leaf in leaves_with_paths:
        arr = np.asarray(leaf)
        buf.append((_path_str(keypath), arr.tobytes(), str(arr.dtype), list(arr.shape)))
        if len(buf) >= _LEAVES_PER_SHARD:
            flush_shard()
    flush_shard()

    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    fsync_dir(ckpt_dir)
    write_latest(ckpt_dir, os.path.basename(final))

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    name = read_latest(ckpt_dir)
    if name is None:
        return None
    path = os.path.join(ckpt_dir, name)
    if not os.path.exists(os.path.join(path, "manifest.json")):
        # torn checkpoint: fall back to newest complete one
        for d in sorted(os.listdir(ckpt_dir), reverse=True):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                return int(d.split("_")[1])
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, state_like: Any, step: Optional[int] = None
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of `state_like` (arrays or SDS)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    by_path: Dict[str, np.ndarray] = {}
    shards = {e["shard"] for e in manifest["leaves"]}
    for fname in shards:
        with open(os.path.join(path, fname), "rb") as f:
            payload = msgpack.unpackb(compression.decompress(f.read()), raw=False)
        for p, data, dt, sh in payload:
            by_path[p] = np.frombuffer(data, dtype=dt).reshape(sh)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(state_like)[0]
    treedef = jax.tree_util.tree_structure(state_like)
    out = []
    for keypath, leaf in leaves_with_paths:
        p = _path_str(keypath)
        arr = by_path[p]
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        out.append(jax.numpy.asarray(arr).astype(want_dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
