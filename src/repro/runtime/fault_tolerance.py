"""Fault tolerance for 1000+ node deployments: failure detection, checkpoint/
restart, straggler mitigation, elastic re-meshing.

On real multi-pod hardware the signals come from the JAX distributed runtime
(missed heartbeats, NCCL/ICI timeouts); this module implements the control
plane against an injectable clock/worker set so the logic is fully testable
on one CPU (tests/test_fault_tolerance.py), and the train driver
(launch/train.py) wires it to real steps.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# failure detection
# ---------------------------------------------------------------------------
class HeartbeatMonitor:
    """Workers report heartbeats; miss `timeout_s` -> declared failed."""

    def __init__(self, workers: Sequence[str], timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.last_seen: Dict[str, float] = {w: clock() for w in workers}
        self.failed: set = set()

    def beat(self, worker: str) -> None:
        if worker not in self.failed:
            self.last_seen[worker] = self.clock()

    def check(self) -> List[str]:
        now = self.clock()
        newly = [
            w for w, t in self.last_seen.items()
            if w not in self.failed and now - t > self.timeout_s
        ]
        self.failed.update(newly)
        return newly

    @property
    def healthy(self) -> List[str]:
        return [w for w in self.last_seen if w not in self.failed]


# ---------------------------------------------------------------------------
# straggler mitigation
# ---------------------------------------------------------------------------
@dataclass
class StragglerEvent:
    step: int
    worker: str
    duration_s: float
    deadline_s: float
    action: str          # "backup_dispatched" | "observed"


class StragglerMitigator:
    """Per-step duration tracking with a rolling median deadline. A worker
    exceeding `factor` x median gets its shard re-dispatched to a backup
    (speculative execution — first result wins, à la backup tasks)."""

    def __init__(self, factor: float = 3.0, window: int = 32, min_samples: int = 5):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.durations: List[float] = []
        self.events: List[StragglerEvent] = []

    def record(self, duration_s: float) -> None:
        self.durations.append(duration_s)
        if len(self.durations) > self.window:
            self.durations.pop(0)

    def deadline(self) -> Optional[float]:
        if len(self.durations) < self.min_samples:
            return None
        s = sorted(self.durations)
        return s[len(s) // 2] * self.factor

    def check(self, step: int, worker: str, duration_s: float) -> Optional[StragglerEvent]:
        dl = self.deadline()
        self.record(duration_s)
        if dl is not None and duration_s > dl:
            ev = StragglerEvent(step, worker, duration_s, dl, "backup_dispatched")
            self.events.append(ev)
            return ev
        return None


# ---------------------------------------------------------------------------
# elastic re-meshing
# ---------------------------------------------------------------------------
# allowed (pod, data, model) configurations, largest first; `model` is kept
# constant so parameter shardings stay valid and only DP width changes —
# re-lowering + checkpoint restore is then sufficient (no resharding of the
# TP dimension needed).
DEFAULT_LADDER: Tuple[Tuple[int, int, int], ...] = (
    (2, 16, 16), (1, 16, 16), (1, 8, 16), (1, 4, 16),
)


class ElasticScaler:
    def __init__(self, ladder: Sequence[Tuple[int, int, int]] = DEFAULT_LADDER):
        self.ladder = list(ladder)

    def pick(self, devices_available: int) -> Optional[Tuple[int, int, int]]:
        for shape in self.ladder:
            need = shape[0] * shape[1] * shape[2]
            if devices_available >= need:
                return shape
        return None

    def replan(self, devices_available: int):
        """Returns (mesh_shape, axis_names) or None if unservable."""
        shape = self.pick(devices_available)
        if shape is None:
            return None
        if shape[0] == 1:
            return (shape[1], shape[2]), ("data", "model")
        return shape, ("pod", "data", "model")


# ---------------------------------------------------------------------------
# crash injection (durable write path)
# ---------------------------------------------------------------------------
class SimulatedCrash(RuntimeError):
    """Raised by CrashInjector at the configured durability event. Test
    harnesses treat it as process death: the in-memory system is discarded
    and recovery must proceed from disk alone."""


class CrashInjector:
    """Deterministic kill-point hook for the durable write path.

    The journaled store (core/journal.py) calls ``tick(event)`` at every
    durability transition — after a journal append, after an op applies to
    the in-memory forest, before a snapshot commits, after the journal
    rotates. ``crash_at=k`` raises :class:`SimulatedCrash` at the k-th event
    (1-based), so a test sweep over k exercises a kill at EVERY boundary the
    exactly-once recovery contract must survive. ``crash_at=None`` records
    the event trace without crashing (used to size the sweep)).

    ``obs`` (a :class:`repro.obs.Observability`) mirrors every tick into the
    trace sink as a ``durability/<event>`` point event under whichever span
    is open at the time (e.g. ``journal.append`` or ``journal.checkpoint``),
    so crash sweeps can assert span-level event ordering straight from the
    trace (tests/test_durability.py)."""

    def __init__(self, crash_at: Optional[int] = None, obs=None):
        self.crash_at = crash_at
        self.obs = obs
        self.events = 0
        self.fired = False
        self.trace: List[str] = []

    def tick(self, event: str) -> None:
        if self.fired:
            return
        self.events += 1
        self.trace.append(event)
        if self.obs is not None:
            self.obs.event("durability/" + event, n=self.events)
        if self.crash_at is not None and self.events >= self.crash_at:
            self.fired = True
            raise SimulatedCrash(f"injected crash at event #{self.events} ({event})")


# ---------------------------------------------------------------------------
# driver-side recovery orchestration
# ---------------------------------------------------------------------------
@dataclass
class RecoveryLog:
    restarts: int = 0
    straggler_backups: int = 0
    remesh_events: List[Tuple[int, Tuple[int, ...]]] = field(default_factory=list)


class FaultTolerantRunner:
    """Wraps a step function with checkpoint/restart + straggler accounting.

    `inject_failure(at_step)` is the test hook: raises a simulated worker
    loss at that step; the runner restores from the last checkpoint and
    continues (optionally on a smaller mesh via ElasticScaler)."""

    def __init__(self, step_fn, save_fn, restore_fn, *,
                 checkpoint_every: int = 50,
                 mitigator: Optional[StragglerMitigator] = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.checkpoint_every = checkpoint_every
        self.mitigator = mitigator or StragglerMitigator()
        self.log = RecoveryLog()
        self._failures: Dict[int, str] = {}

    def inject_failure(self, at_step: int, worker: str = "worker_7") -> None:
        self._failures[at_step] = worker

    def run(self, state, start_step: int, num_steps: int, batch_fn):
        step = start_step
        while step < start_step + num_steps:
            if step in self._failures:
                del self._failures[step]
                self.log.restarts += 1
                state, restored_step = self.restore_fn()
                step = restored_step
                continue
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch_fn(step))
            dur = time.perf_counter() - t0
            ev = self.mitigator.check(step, "worker_0", dur)
            if ev is not None:
                self.log.straggler_backups += 1
            step += 1
            if step % self.checkpoint_every == 0:
                self.save_fn(state, step)
        return state, step
