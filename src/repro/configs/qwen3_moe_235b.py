"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B; hf] (assigned 235B-A22B scale)

The paper's own builder backbone is Qwen3-30B-A3B — this arch family is the
most representative of the paper's write-path workload (chunk extraction
prefill), hence one of the three hillclimb cells (EXPERIMENTS.md §Perf).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,              # per-expert intermediate
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    rope_theta=1000000.0,
    mlp_activation="swiglu",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen3-moe-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    num_experts=8,
    experts_per_token=2,
    max_seq_len=128,
)
