"""whisper-base [audio] — enc-dec, conv frontend STUB (precomputed frame
embeddings via input_specs). [arXiv:2212.04356; unverified]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,            # decoder layers
    encoder_layers=6,
    encoder_seq_len=1500,    # frames after (stubbed) conv frontend
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    mlp_activation="gelu",
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions
    max_seq_len=32768,
)

SMOKE_CONFIG = CONFIG.replace(
    name="whisper-smoke",
    num_layers=2,
    encoder_layers=2,
    encoder_seq_len=32,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=128,
)
