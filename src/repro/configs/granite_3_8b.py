"""granite-3-8b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10000.0,
    mlp_activation="swiglu",
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="granite-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=128,
)
