"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    mlp_activation="swiglu",
)

SMOKE_CONFIG = CONFIG.replace(
    name="phi3-mini-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=128,
)
