"""rwkv6-1.6b [ssm] — Finch, attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]

Runs long_500k: constant-size recurrent state per layer.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,            # d_model / rwkv_head_size
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_size=64,
    mlp_activation="relu_sq",  # rwkv channel-mix uses squared relu
    max_seq_len=1048576,
)

SMOKE_CONFIG = CONFIG.replace(
    name="rwkv6-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    rwkv_head_size=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=256,
)
