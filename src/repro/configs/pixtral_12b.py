"""pixtral-12b [vlm] — pixtral-ViT frontend STUB + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

input_specs() provides precomputed patch embeddings (batch, num_patches,
d_model) prepended to the token sequence.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    num_patches=64,
    rope_theta=1000000000.0,
    mlp_activation="swiglu",
)

SMOKE_CONFIG = CONFIG.replace(
    name="pixtral-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    num_patches=4,
    max_seq_len=128,
)
