"""olmoe-1b-7b [moe] — 64 experts top-8, GQA kv=16. [arXiv:2409.02060; hf]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,              # per-expert intermediate
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
    rope_theta=10000.0,
    mlp_activation="swiglu",
)

SMOKE_CONFIG = CONFIG.replace(
    name="olmoe-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    num_experts=8,
    experts_per_token=2,
    max_seq_len=128,
)
