"""Assigned input-shape sets (LM-family: seq_len x global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache / recurrent state of seq_len), NOT ``train_step``. ``long_500k`` runs
only for sub-quadratic archs (ssm/hybrid) per the assignment.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.config import ModelConfig, ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped). long_500k only for ssm/hybrid."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: long_500k skipped per assignment (see DESIGN.md §5)"
    return True, ""


def cells_for_arch(cfg: ModelConfig) -> List[ShapeConfig]:
    out = []
    for name in SHAPE_ORDER:
        ok, _ = shape_applicable(cfg, SHAPES[name])
        if ok:
            out.append(SHAPES[name])
    return out
