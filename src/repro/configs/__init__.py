"""Assigned-architecture registry.

Each module exports ``CONFIG`` (full-size, dry-run only) and ``SMOKE_CONFIG``
(reduced, CPU-runnable). ``get_config(name)`` / ``list_archs()`` are the public
entry points used by --arch flags in launch scripts.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig
from repro.configs.shapes import SHAPES, get_shape, cells_for_arch  # noqa: F401

ARCHS: List[str] = [
    "whisper_base",
    "rwkv6_1b6",
    "zamba2_7b",
    "qwen3_moe_235b",
    "olmoe_1b_7b",
    "starcoder2_7b",
    "phi3_mini",
    "llama3_8b",
    "granite_3_8b",
    "pixtral_12b",
]

# hyphen/dot aliases accepted from CLI
_ALIASES = {
    "whisper-base": "whisper_base",
    "rwkv6-1.6b": "rwkv6_1b6",
    "zamba2-7b": "zamba2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "starcoder2-7b": "starcoder2_7b",
    "phi3-mini-3.8b": "phi3_mini",
    "llama3-8b": "llama3_8b",
    "granite-3-8b": "granite_3_8b",
    "pixtral-12b": "pixtral_12b",
}


def _module(name: str):
    canon = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if canon not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{canon}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE_CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
