"""starcoder2-7b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    rope_theta=1000000.0,
    mlp_activation="gelu",
)

SMOKE_CONFIG = CONFIG.replace(
    name="starcoder2-smoke",
    num_layers=2,
    d_model=72,
    num_heads=6,
    num_kv_heads=2,
    head_dim=12,
    d_ff=144,
    vocab_size=512,
    max_seq_len=128,
)
