"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block every 6 layers.
[arXiv:2411.15242; unverified]

ssm_state=64 per assignment. Runs long_500k (state-based backbone; the shared
attention applications keep KV caches but decode is O(L) per step).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,             # shared-block MLP
    vocab_size=32000,
    ssm_state_dim=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    attn_every=6,
    rope_theta=10000.0,
    mlp_activation="swiglu",
    max_seq_len=1048576,
)

SMOKE_CONFIG = CONFIG.replace(
    name="zamba2-smoke",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    ssm_state_dim=16,
    ssm_head_dim=16,
    attn_every=2,
    max_seq_len=256,
)
