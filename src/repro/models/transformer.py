"""Decoder-only LM: dense, MoE, and VLM (patch-embedding stub) families.

Layers are stacked along a leading dim and scanned (`lax.scan`) with
optional remat — keeps the HLO size O(1) in depth, which matters both for
94-layer MoE dry-run compiles and for real-TPU compile latency.

Three entry points per model (see factory.Model):
  * loss(params, batch)                  — train forward + xent
  * prefill(params, batch)               — returns (last-token logits, cache)
  * decode(params, batch, cache)         — one token against the cache
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.launch.sharding import DATA_AXES, MODEL_AXIS, constrain
from repro.models import layers as L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers, k_out = jax.random.split(key, 3)

    def init_layer(k):
        ka, km, = jax.random.split(k, 2)
        p = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": L.attn_init(ka, cfg, dtype),
        }
        if cfg.family == "moe":
            p["moe"] = L.moe_init(km, cfg, dtype)
        else:
            p["mlp"] = L.mlp_init(km, cfg, dtype)
        return p

    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(init_layer)(layer_keys)

    params: Dict[str, Any] = {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab_size, dtype)
    return params


# ---------------------------------------------------------------------------
# shared trunk
# ---------------------------------------------------------------------------
def _layer_fwd(cfg: ModelConfig, p, x, positions):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + L.attention_prefill(p["attn"], h, cfg, positions)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = L.moe_block(p["moe"], h, cfg)
    else:
        y, aux = L.mlp_block(p["mlp"], h, cfg), jnp.asarray(0.0, jnp.float32)
    return x + y, aux


def trunk(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    """x: (B, S, D) embeddings -> (hidden (B, S, D), aux_loss)."""

    def body(carry, p):
        x = carry
        fwd = functools.partial(_layer_fwd, cfg)
        if cfg.remat:
            fwd = jax.checkpoint(fwd)
        x, aux = fwd(p, x, positions)
        return x, aux

    if cfg.scan_layers:
        x, auxs = jax.lax.scan(body, x, params["layers"])
        aux = jnp.sum(auxs)
    else:
        aux = jnp.asarray(0.0, jnp.float32)
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a, _i=i: a[_i], params["layers"])
            x, a = body(x, p)
            aux = aux + a
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def _logits(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["unembed"]
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return constrain(logits, DATA_AXES, None, MODEL_AXIS) if logits.ndim == 3 else logits


def _embed_batch(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    """Token embedding (+ VLM patch prepend). Returns (x, label_mask_extra)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        patches = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    return constrain(x, DATA_AXES, None, None)


# ---------------------------------------------------------------------------
# train loss
# ---------------------------------------------------------------------------
def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    x = _embed_batch(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    h, aux = trunk(params, cfg, x, positions)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        P = batch["patch_embeds"].shape[1]
        h = h[:, P:]
    logits = _logits(params, cfg, h)
    loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, jax.Array]:
    dtype = jnp.dtype(cfg.dtype)
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array], max_len: int):
    """Full-sequence forward; returns (last logits (B, V), cache)."""
    x = _embed_batch(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    def body(carry, p):
        x = carry

        def fwd(p, x):
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            att, (k, v) = L.attention_prefill(
                p["attn"], h, cfg, positions, return_kv=True
            )
            x = x + att
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, _ = L.moe_block(p["moe"], h, cfg)
            else:
                y = L.mlp_block(p["mlp"], h, cfg)
            return x + y, (k, v)

        if cfg.remat:
            fwd = jax.checkpoint(fwd)
        x, kv = fwd(p, x)
        return x, kv

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h[:, -1])

    pad = max_len - S
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {
        "k": ks.astype(jnp.dtype(cfg.dtype)),
        "v": vs.astype(jnp.dtype(cfg.dtype)),
        "lengths": jnp.full((B,), S, jnp.int32),
    }
    return logits, cache


def decode_step(params, cfg: ModelConfig, batch: Dict[str, jax.Array], cache):
    """One-token decode. batch: {"tokens": (B,) int32} (+ patch stub ignored).
    Returns (logits (B, V), new cache)."""
    tok = batch["tokens"]
    x = params["embed"][tok]                       # (B, D)
    x = constrain(x, DATA_AXES, None)
    lengths = cache["lengths"]

    def body(carry, scanned):
        x = carry
        p, kc, vc = scanned

        def fwd(p, x, kc, vc):
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            att, kc2, vc2 = L.attention_decode(p["attn"], h, cfg, kc, vc, lengths)
            x = x + att
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, _ = L.moe_block(p["moe"], h[:, None, :], cfg)
                y = y[:, 0]
            else:
                y = L.mlp_block(p["mlp"], h, cfg)
            return x + y, kc2, vc2

        x, kc2, vc2 = fwd(p, x, kc, vc)
        return x, (kc2, vc2)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h)
    new_cache = {"k": ks, "v": vs, "lengths": lengths + 1}
    return logits, new_cache
