"""Shared model building blocks: norms, RoPE, attention, MLP, MoE.

All functions are pure; parameters are plain dicts of jnp arrays. Layer
parameter dicts are stacked along a leading layer dim and scanned
(`lax.scan`) by the model definitions. Activation sharding constraints use
`launch.sharding.constrain`, which no-ops outside a mesh context.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.kernels import ops, ref
from repro.launch.sharding import DATA_AXES, MODEL_AXIS, constrain, get_abstract_mesh

# jax.shard_map was promoted out of jax.experimental after the pinned version
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def heads_axis(num_heads: int):
    """`model` if the head count divides evenly over the mesh's model axis,
    else None (replicate — avoids involuntary SPMD remat on GQA kv heads
    narrower than the TP width)."""
    am = get_abstract_mesh()
    if am.empty or MODEL_AXIS not in am.axis_names:
        return None
    size = dict(am.shape)[MODEL_AXIS]
    return MODEL_AXIS if num_heads % size == 0 else None


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def group_rms_norm(x: jax.Array, gamma: jax.Array, num_heads: int, eps: float = 1e-5) -> jax.Array:
    """Per-head RMS norm over the trailing dim split into heads (RWKV wkv out)."""
    *lead, D = x.shape
    xh = x.reshape(*lead, num_heads, D // num_heads).astype(jnp.float32)
    var = jnp.mean(xh * xh, axis=-1, keepdims=True)
    y = (xh * jax.lax.rsqrt(var + eps)).reshape(*lead, D)
    return (y * gamma.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., H, D) with positions (..., S) or (...,)."""
    D = x.shape[-1]
    half = D // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # (half,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (S, D)


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.q_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, dtype),
        "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model, dtype),
    }


def attention_prefill(
    p: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
    *, causal: bool = True, return_kv: bool = False,
):
    """x: (B, S, D). Returns (out, (k, v) if return_kv)."""
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    kv_ax = heads_axis(cfg.num_kv_heads)
    q = constrain(q, DATA_AXES, None, heads_axis(cfg.num_heads), None)
    k = constrain(k, DATA_AXES, None, kv_ax, None)
    v = constrain(v, DATA_AXES, None, kv_ax, None)
    if cfg.attention_impl == "reference" and S > 1024 and causal:
        o = ref.blockwise_causal_attention(q, k, v)
    elif cfg.attention_impl.startswith("pallas"):
        o = ops.attention(q, k, v, causal=causal, impl=cfg.attention_impl)
    else:
        o = ops.attention(q, k, v, causal=causal, impl="reference")
    out = o.reshape(B, S, cfg.q_dim) @ p["wo"]
    out = constrain(out, DATA_AXES, None, None)
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(
    p: Params, x: jax.Array, cfg: ModelConfig,
    k_cache: jax.Array, v_cache: jax.Array, lengths: jax.Array,
):
    """One-token decode. x: (B, D); caches (B, Smax, Hkv, Dh); lengths (B,).
    Returns (out (B, D), new_k_cache, new_v_cache)."""
    B, _ = x.shape
    q = (x @ p["wq"]).reshape(B, cfg.num_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(B, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(B, cfg.num_kv_heads, cfg.head_dim)
    if cfg.rope_theta > 0:
        q = rope(q, lengths, cfg.rope_theta)
        k = rope(k, lengths, cfg.rope_theta)

    def upd(cache, new, l):
        return jax.lax.dynamic_update_slice(cache, new[None], (l, 0, 0))

    # KV-cache sharding: heads over `model` when they divide the TP width;
    # otherwise shard the SEQUENCE dim (split-KV / flash-decode style — XLA
    # turns the softmax reductions into small per-layer all-reduces, and the
    # multi-GB cache stays fully distributed).
    kv_ax = heads_axis(cfg.num_kv_heads)
    seq_ax = MODEL_AXIS if kv_ax is None else None
    k_cache = jax.vmap(upd)(k_cache, k, lengths)
    v_cache = jax.vmap(upd)(v_cache, v, lengths)
    k_cache = constrain(k_cache, DATA_AXES, seq_ax, kv_ax, None)
    v_cache = constrain(v_cache, DATA_AXES, seq_ax, kv_ax, None)
    impl = cfg.attention_impl if cfg.attention_impl.startswith("pallas") else "reference"
    o = ops.decode_attention(q, k_cache, v_cache, lengths + 1, impl=impl)
    out = o.reshape(B, cfg.q_dim) @ p["wo"]
    return constrain(out, DATA_AXES, None), k_cache, v_cache


def cross_attention(
    p: Params, x: jax.Array, cfg: ModelConfig,
    k: jax.Array, v: jax.Array,
):
    """x: (B, Sq, D) or (B, D); k/v: (B, Skv, Hkv, Dh) precomputed."""
    single = x.ndim == 2
    if single:
        x = x[:, None, :]
    B, Sq, _ = x.shape
    q = (x @ p["wq"]).reshape(B, Sq, cfg.num_heads, cfg.head_dim)
    o = ref.cross_attention_ref(q, k, v)
    out = o.reshape(B, Sq, cfg.q_dim) @ p["wo"]
    return out[:, 0] if single else out


def cross_kv(p: Params, enc_out: jax.Array, cfg: ModelConfig):
    B, Skv, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    return k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> Params:
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_activation == "swiglu":
        return {
            "w_gate": dense_init(ks[0], cfg.d_model, F, dtype),
            "w_up": dense_init(ks[1], cfg.d_model, F, dtype),
            "w_down": dense_init(ks[2], F, cfg.d_model, dtype),
        }
    return {
        "wi": dense_init(ks[0], cfg.d_model, F, dtype),
        "wd": dense_init(ks[1], F, cfg.d_model, dtype),
    }


def mlp_block(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp_activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = constrain(h, DATA_AXES, None, MODEL_AXIS) if h.ndim == 3 else h
        out = h @ p["w_down"]
    else:
        h = x @ p["wi"]
        h = jax.nn.gelu(h) if cfg.mlp_activation == "gelu" else jnp.square(jax.nn.relu(h))
        h = constrain(h, DATA_AXES, None, MODEL_AXIS) if h.ndim == 3 else h
        out = h @ p["wd"]
    return constrain(out, DATA_AXES, None, None) if out.ndim == 3 else out


# ---------------------------------------------------------------------------
# MoE block — dropless-ish capacity dispatch via sort-free rank + gather
# ---------------------------------------------------------------------------
def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    scale_in = 1.0 / math.sqrt(D)
    scale_out = 1.0 / math.sqrt(F)
    return {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "we_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * scale_in).astype(dtype),
        "we_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * scale_in).astype(dtype),
        "we_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32) * scale_out).astype(dtype),
    }


def _moe_dispatch_compute(xf, gate_w, gate_i, we_gate, we_up, we_down,
                          *, E: int, K: int, C: int, e_lo, E_local: int):
    """Capacity dispatch + expert FFN for experts [e_lo, e_lo + E_local).

    Dispatch avoids the O(T·E·C) one-hot einsum: token ranks within each
    expert come from an argsort over expert assignments, token indices are
    scattered into a compact (E_local·C) buffer, expert inputs are a gather.
    Runs on LOCAL tokens only (see moe_block).
    """
    T, D = xf.shape
    eidx = gate_i.reshape(-1)                               # (T*K,)
    tok = jnp.repeat(jnp.arange(T), K)
    w_flat = gate_w.reshape(-1)

    # rank of each (token, choice) within its expert (over ALL E experts so
    # capacity semantics are identical regardless of the expert sharding)
    order = jnp.argsort(eidx, stable=True)
    sorted_e = eidx[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(T * K) - start[sorted_e]
    rank = jnp.zeros((T * K,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = (rank < C) & (eidx >= e_lo) & (eidx < e_lo + E_local)
    slot = (eidx - e_lo) * C + rank                         # (T*K,) local slots

    buf = jnp.full((E_local * C,), T, jnp.int32)
    buf = buf.at[jnp.where(keep, slot, E_local * C)].set(
        tok.astype(jnp.int32), mode="drop"
    )
    x_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    expert_in = x_pad[buf].reshape(E_local, C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, we_gate)) * jnp.einsum(
        "ecd,edf->ecf", expert_in, we_up
    )
    expert_out = jnp.einsum("ecf,efd->ecd", h, we_down).reshape(E_local * C, D)

    gathered = expert_out[jnp.where(keep, slot, 0)]
    gathered = gathered * (keep.astype(gathered.dtype) * w_flat.astype(gathered.dtype))[:, None]
    return jnp.sum(gathered.reshape(T, K, D), axis=1)       # partial (local experts)


def moe_block(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE with per-expert capacity, expert-parallel over
    the `model` axis.

    Routing (cheap) runs replicated; dispatch + expert FFN run under
    shard_map so tokens NEVER leave their data shard: each device gathers its
    local tokens for the experts it owns and the partial outputs are combined
    with ONE psum over `model` per layer — the same collective a dense TP
    layer pays. (The naive global-gather formulation all-gathers every token
    per layer; see EXPERIMENTS.md §Perf for the measured difference.)

    Returns (out, aux_loss).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S

    def gate(xl):
        """Router + top-k + Switch aux loss over local tokens (tl, D)."""
        logits = xl.astype(jnp.float32) @ p["router"].astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gw, gi = jax.lax.top_k(probs, K)
        gw = (gw / (jnp.sum(gw, axis=-1, keepdims=True) + 1e-9)).astype(xl.dtype)
        me = jnp.mean(probs, axis=0)
        frac = jnp.zeros((E,), jnp.float32).at[gi.reshape(-1)].add(1.0) / (probs.shape[0] * K)
        return gw, gi, E * jnp.sum(frac * me)

    am = get_abstract_mesh()
    names = () if am.empty else tuple(am.axis_names)
    if MODEL_AXIS in names and E % dict(am.shape)[MODEL_AXIS] == 0:
        tp = dict(am.shape)[MODEL_AXIS]
        dp_axes = tuple(a for a in DATA_AXES if a in names)
        E_local = E // tp
        dp = 1
        for a in dp_axes:
            dp *= dict(am.shape)[a]
        T_local = T // dp
        C = max(int(math.ceil(T_local * K / E * cfg.moe_capacity_factor)), 1)

        fsdp_axes = dp_axes if cfg.moe_fsdp_params else ()

        def local(xb, wg, wu, wd):
            # everything token-local happens INSIDE the shard_map: routing,
            # top-k, dispatch — no boundary tensors beyond x itself
            tl = xb.shape[0] * xb.shape[1]
            xl = xb.reshape(tl, D)
            gw, gi, aux = gate(xl)
            if dp_axes:
                aux = jax.lax.pmean(aux, dp_axes)
            e_lo = jax.lax.axis_index(MODEL_AXIS) * E_local
            # FSDP: expert weights arrive sharded over the data axes on dim 1;
            # gather just-in-time (backward = reduce-scatter of the grads)
            if fsdp_axes:
                wg = jax.lax.all_gather(wg, fsdp_axes, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, fsdp_axes, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, fsdp_axes, axis=1, tiled=True)
            y = _moe_dispatch_compute(
                xl, gw, gi, wg, wu, wd,
                E=E, K=K, C=C, e_lo=e_lo, E_local=E_local,
            )
            # combine partials in the activation dtype (not f32)
            y = jax.lax.psum(y.astype(xb.dtype), MODEL_AXIS)
            return y.reshape(xb.shape), aux

        pspec_x = P(dp_axes if dp_axes else None, None, None)
        pspec_w = P(MODEL_AXIS, fsdp_axes if fsdp_axes else None, None)
        y, aux = _shard_map(
            local, mesh=am,
            in_specs=(pspec_x, pspec_w, pspec_w, pspec_w),
            out_specs=(pspec_x, P()),
        )(x, p["we_gate"], p["we_up"], p["we_down"])
        return constrain(y, DATA_AXES, None, None), aux

    # single-device / non-divisible fallback: same math, all experts local
    gate_w, gate_i, aux = gate(x.reshape(T, D))
    C = max(int(math.ceil(T * K / E * cfg.moe_capacity_factor)), 1)
    y = _moe_dispatch_compute(
        x.reshape(T, D), gate_w, gate_i,
        p["we_gate"], p["we_up"], p["we_down"],
        E=E, K=K, C=C, e_lo=jnp.asarray(0, jnp.int32), E_local=E,
    )
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    """logits (B, S, V), labels (B, S) int32. Mean over valid positions."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    m = mask.astype(jnp.float32)
    return -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)
