from repro.models.factory import get_model, input_specs, param_specs  # noqa: F401
