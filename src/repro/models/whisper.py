"""Whisper-style encoder-decoder backbone.

The conv audio frontend is STUBBED per the assignment: batches carry
precomputed frame embeddings (B, S_enc, D) under "frames" (what the two conv
layers + GELU would produce). Encoder: bidirectional self-attention + GELU
MLP. Decoder: causal self-attention + cross-attention to encoder output.

Serving: prefill encodes frames once, precomputes per-layer cross K/V, and
fills the decoder self-attn KV cache; decode_step extends one token.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.launch.sharding import DATA_AXES, MODEL_AXIS, constrain
from repro.models import layers as L


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    k_emb, k_enc, k_dec, k_out = jax.random.split(key, 4)

    def init_enc_layer(k):
        ka, km = jax.random.split(k)
        return {
            "ln1": jnp.ones((D,), dtype),
            "ln1b": jnp.zeros((D,), dtype),
            "ln2": jnp.ones((D,), dtype),
            "ln2b": jnp.zeros((D,), dtype),
            "attn": L.attn_init(ka, cfg, dtype),
            "mlp": L.mlp_init(km, cfg, dtype),
        }

    def init_dec_layer(k):
        ka, kc, km = jax.random.split(k, 3)
        return {
            "ln1": jnp.ones((D,), dtype),
            "ln1b": jnp.zeros((D,), dtype),
            "ln_x": jnp.ones((D,), dtype),
            "ln_xb": jnp.zeros((D,), dtype),
            "ln2": jnp.ones((D,), dtype),
            "ln2b": jnp.zeros((D,), dtype),
            "self_attn": L.attn_init(ka, cfg, dtype),
            "cross_attn": L.attn_init(kc, cfg, dtype),
            "mlp": L.mlp_init(km, cfg, dtype),
        }

    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, D, dtype),
        "enc_layers": jax.vmap(init_enc_layer)(jax.random.split(k_enc, cfg.encoder_layers)),
        "dec_layers": jax.vmap(init_dec_layer)(jax.random.split(k_dec, cfg.num_layers)),
        "enc_norm": jnp.ones((D,), dtype),
        "enc_norm_b": jnp.zeros((D,), dtype),
        "dec_norm": jnp.ones((D,), dtype),
        "dec_norm_b": jnp.zeros((D,), dtype),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, D) stubbed conv-frontend output."""
    B, S, D = frames.shape
    x = frames + L.sinusoidal_positions(S, D).astype(frames.dtype)[None]
    x = constrain(x, DATA_AXES, None, None)
    positions = jnp.arange(S)[None, :]

    def body(carry, p):
        x = carry

        def fwd(p, x):
            h = L.layer_norm(x, p["ln1"], p["ln1b"], cfg.norm_eps)
            x = x + L.attention_prefill(p["attn"], h, cfg, positions, causal=False)
            h = L.layer_norm(x, p["ln2"], p["ln2b"], cfg.norm_eps)
            return x + L.mlp_block(p["mlp"], h, cfg), None

        if cfg.remat:
            fwd = jax.checkpoint(fwd)
        x, _ = fwd(p, x)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layer_norm(x, params["enc_norm"], params["enc_norm_b"], cfg.norm_eps)


def _decoder_seq(params, cfg: ModelConfig, tokens, enc_out, *, collect_kv: bool):
    B, S = tokens.shape
    D = cfg.d_model
    x = params["embed"][tokens] + L.sinusoidal_positions(S, D).astype(
        jnp.dtype(cfg.dtype)
    )[None]
    x = constrain(x, DATA_AXES, None, None)
    positions = jnp.arange(S)[None, :]

    def body(carry, p):
        x = carry

        def fwd(p, x):
            h = L.layer_norm(x, p["ln1"], p["ln1b"], cfg.norm_eps)
            att, kv = L.attention_prefill(
                p["self_attn"], h, cfg, positions, return_kv=True
            )
            x = x + att
            h = L.layer_norm(x, p["ln_x"], p["ln_xb"], cfg.norm_eps)
            ck, cv = L.cross_kv(p["cross_attn"], enc_out, cfg)
            x = x + L.cross_attention(p["cross_attn"], h, cfg, ck, cv)
            h = L.layer_norm(x, p["ln2"], p["ln2b"], cfg.norm_eps)
            x = x + L.mlp_block(p["mlp"], h, cfg)
            return x, (kv, (ck, cv))

        if cfg.remat and not collect_kv:
            fwd = jax.checkpoint(fwd)
        x, kvs = fwd(p, x)
        return x, kvs

    x, (self_kv, cross_kv_all) = jax.lax.scan(body, x, params["dec_layers"])
    h = L.layer_norm(x, params["dec_norm"], params["dec_norm_b"], cfg.norm_eps)
    return h, self_kv, cross_kv_all


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    enc_out = encode(params, cfg, batch["frames"])
    h, _, _ = _decoder_seq(params, cfg, batch["tokens"], enc_out, collect_kv=False)
    logits = h @ params["embed"].T  # whisper ties output to embedding
    loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"xent": loss}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    Lc = cfg.num_layers
    return {
        "self_k": jax.ShapeDtypeStruct((Lc, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
        "self_v": jax.ShapeDtypeStruct((Lc, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
        "cross_k": jax.ShapeDtypeStruct((Lc, batch, cfg.encoder_seq_len, cfg.num_kv_heads, cfg.head_dim), dt),
        "cross_v": jax.ShapeDtypeStruct((Lc, batch, cfg.encoder_seq_len, cfg.num_kv_heads, cfg.head_dim), dt),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array], max_len: int):
    enc_out = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    h, self_kv, cross_kv_all = _decoder_seq(params, cfg, tokens, enc_out, collect_kv=True)
    logits = h[:, -1] @ params["embed"].T
    ks, vs = self_kv
    cks, cvs = cross_kv_all
    pad = max_len - S
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    dt = jnp.dtype(cfg.dtype)
    cache = {
        "self_k": ks.astype(dt),
        "self_v": vs.astype(dt),
        "cross_k": cks.astype(dt),
        "cross_v": cvs.astype(dt),
        "lengths": jnp.full((B,), S, jnp.int32),
    }
    return logits, cache


def decode_step(params, cfg: ModelConfig, batch: Dict[str, jax.Array], cache):
    tok = batch["tokens"]
    B = tok.shape[0]
    D = cfg.d_model
    lengths = cache["lengths"]
    pos_tab = L.sinusoidal_positions(cfg.max_seq_len, D).astype(jnp.dtype(cfg.dtype))
    x = params["embed"][tok] + pos_tab[lengths]
    x = constrain(x, DATA_AXES, None)

    def body(carry, scanned):
        x = carry
        p, kc, vc, ck, cv = scanned
        h = L.layer_norm(x, p["ln1"], p["ln1b"], cfg.norm_eps)
        att, kc2, vc2 = L.attention_decode(p["self_attn"], h, cfg, kc, vc, lengths)
        x = x + att
        h = L.layer_norm(x, p["ln_x"], p["ln_xb"], cfg.norm_eps)
        x = x + L.cross_attention(p["cross_attn"], h, cfg, ck, cv)
        h = L.layer_norm(x, p["ln2"], p["ln2b"], cfg.norm_eps)
        x = x + L.mlp_block(p["mlp"], h, cfg)
        return x, (kc2, vc2)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]),
    )
    h = L.layer_norm(x, params["dec_norm"], params["dec_norm_b"], cfg.norm_eps)
    logits = h @ params["embed"].T
    new_cache = dict(cache, self_k=ks, self_v=vs, lengths=lengths + 1)
    return logits, new_cache
