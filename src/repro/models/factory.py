"""Model factory: one uniform interface over all assigned architectures.

    model = get_model(cfg)
    model.init(key) -> params
    model.loss(params, batch) -> (loss, metrics)
    model.prefill(params, batch, max_len) -> (logits, cache)
    model.decode(params, batch, cache) -> (logits, cache)
    model.cache_specs(batch, max_len) -> pytree of ShapeDtypeStruct

`input_specs(cfg, shape)` builds the ShapeDtypeStruct stand-ins for every
model input of a benchmark cell (dry-run pattern: weak-type-correct,
shardable, no device allocation).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import rwkv6, transformer, whisper, zamba2


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    cache_specs: Callable[..., Any]


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer
    elif cfg.family == "ssm":
        mod = rwkv6
    elif cfg.family == "hybrid":
        mod = zamba2
    elif cfg.family == "encdec":
        mod = whisper
    else:
        raise ValueError(cfg.family)
    return Model(
        cfg=cfg,
        init=functools.partial(mod.init_params, cfg),
        loss=lambda params, batch: mod.loss_fn(params, cfg, batch),
        prefill=lambda params, batch, max_len: mod.prefill(params, cfg, batch, max_len),
        decode=lambda params, batch, cache: mod.decode_step(params, cfg, batch, cache),
        cache_specs=functools.partial(mod.cache_specs, cfg),
    )


def param_specs(cfg: ModelConfig) -> Any:
    """Parameter pytree as ShapeDtypeStructs — no allocation (dry-run)."""
    model = get_model(cfg)
    return jax.eval_shape(model.init, jax.random.key(0))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the batch of one benchmark cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct

    if shape.kind == "decode":
        batch: Dict[str, Any] = {"tokens": sds((B,), i32)}
        return batch

    if cfg.family == "encdec":
        # decoder consumes S tokens; frames come from the stubbed frontend
        return {
            "frames": sds((B, cfg.encoder_seq_len, cfg.d_model), dt),
            "tokens": sds((B, S), i32),
            **({"labels": sds((B, S), i32)} if shape.is_train else {}),
        }
    if cfg.family == "vlm":
        P = cfg.num_patches
        return {
            "tokens": sds((B, S - P), i32),
            "patch_embeds": sds((B, P, cfg.d_model), dt),
            **({"labels": sds((B, S - P), i32)} if shape.is_train else {}),
        }
    return {
        "tokens": sds((B, S), i32),
        **({"labels": sds((B, S), i32)} if shape.is_train else {}),
    }


def make_batch(cfg: ModelConfig, shape_kind: str, batch: int, seq: int, key) -> Dict[str, jax.Array]:
    """Concrete random batch for smoke tests / examples (small shapes only)."""
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(ks[0], (batch, cfg.encoder_seq_len, cfg.d_model), dt)
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.random.normal(ks[0], (batch, cfg.num_patches, cfg.d_model), dt)
    if shape_kind == "decode":
        out["tokens"] = jax.random.randint(ks[1], (batch,), 0, cfg.vocab_size)
        return out
    out["tokens"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size)
    if shape_kind == "train":
        out["labels"] = jax.random.randint(ks[2], (batch, seq), 0, cfg.vocab_size)
    return out
