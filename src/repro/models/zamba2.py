"""Zamba2 hybrid: Mamba2 backbone + one SHARED attention+MLP block applied
every `attn_every` layers.

Layer layout for L layers, period A: groups of A mamba layers, each followed
by one application of the shared attention block (same weights every time,
separate KV cache per application). Group params are reshaped to
(G, A, ...) and double-scanned so the HLO stays O(1) in depth. The trailing
L - G*A layers run as a remainder scan.

Decode: per-layer (ssd_state, conv_state) + per-application KV caches.
Because the backbone state is O(1) in context and attention is only at G
applications, this arch runs the long_500k cell.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.launch.sharding import DATA_AXES, MODEL_AXIS, constrain
from repro.models import layers as L
from repro.models import mamba2 as M


def _group_counts(cfg: ModelConfig):
    A = cfg.attn_every
    G = cfg.num_layers // A
    rem = cfg.num_layers - G * A
    return G, A, rem


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    k_emb, k_m, k_attn, k_mlp, k_out = jax.random.split(key, 5)

    def init_mamba_layer(k):
        return {
            "ln": jnp.ones((D,), dtype),
            "mamba": M.mamba2_init(k, cfg, dtype),
        }

    layer_keys = jax.random.split(k_m, cfg.num_layers)
    stacked = jax.vmap(init_mamba_layer)(layer_keys)

    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, D, dtype),
        "layers": stacked,
        "shared_attn": {
            "ln1": jnp.ones((D,), dtype),
            "ln2": jnp.ones((D,), dtype),
            "attn": L.attn_init(k_attn, cfg, dtype),
            "mlp": L.mlp_init(k_mlp, cfg, dtype),
        },
        "final_norm": jnp.ones((D,), dtype),
        "unembed": L.dense_init(k_out, D, cfg.vocab_size, dtype),
    }


def _mamba_layer_seq(cfg, p, x, sst, cst):
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    y, s_new, c_new = M.mamba2_seq(p["mamba"], h, cfg, sst, cst)
    return x + y, s_new, c_new


def _shared_attn_seq(cfg, sp, x, positions, return_kv=False):
    h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
    if return_kv:
        att, kv = L.attention_prefill(sp["attn"], h, cfg, positions, return_kv=True)
    else:
        att = L.attention_prefill(sp["attn"], h, cfg, positions)
        kv = None
    x = x + att
    h = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
    x = x + L.mlp_block(sp["mlp"], h, cfg)
    return (x, kv) if return_kv else x


def _split_groups(tree, G, A):
    """(L, ...) stacked params -> ((G, A, ...) grouped, (rem, ...) tail)."""
    grouped = jax.tree.map(lambda a: a[: G * A].reshape((G, A) + a.shape[1:]), tree)
    tail = jax.tree.map(lambda a: a[G * A:], tree)
    return grouped, tail


def _run_seq(params, cfg: ModelConfig, x, states, *, collect_kv: bool):
    """x: (B, T, D). states: {"ssd": (L,...), "conv": (L,...), "kv"?: ...}."""
    B, T, _ = x.shape
    G, A, rem = _group_counts(cfg)
    positions = jnp.arange(T)[None, :]
    sp = params["shared_attn"]

    grouped, tail = _split_groups(params["layers"], G, A)
    ssd_g, ssd_t = _split_groups(states["ssd"], G, A)
    conv_g, conv_t = _split_groups(states["conv"], G, A)

    def inner_body(carry, scanned):
        x = carry
        p, sst, cst = scanned
        fwd = functools.partial(_mamba_layer_seq, cfg)
        if cfg.remat:
            fwd = jax.checkpoint(fwd)
        x, s_new, c_new = fwd(p, x, sst, cst)
        return x, (s_new, c_new)

    def outer_body(carry, scanned):
        x = carry
        gp, gs, gc = scanned
        x, (s_new, c_new) = jax.lax.scan(inner_body, x, (gp, gs, gc))
        if collect_kv:
            x, kv = _shared_attn_seq(cfg, sp, x, positions, return_kv=True)
            return x, (s_new, c_new, kv)
        x = _shared_attn_seq(cfg, sp, x, positions)
        return x, (s_new, c_new)

    if collect_kv:
        x, (ssd_new, conv_new, kvs) = jax.lax.scan(outer_body, x, (grouped, ssd_g, conv_g))
    else:
        x, (ssd_new, conv_new) = jax.lax.scan(outer_body, x, (grouped, ssd_g, conv_g))
        kvs = None

    # remainder mamba layers
    if rem > 0:
        x, (ssd_tail, conv_tail) = jax.lax.scan(inner_body, x, (tail, ssd_t, conv_t))
    else:
        ssd_tail, conv_tail = ssd_t, conv_t

    def unsplit(g, t):
        flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), g)
        return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), flat, t)

    new_states = {
        "ssd": unsplit(ssd_new, ssd_tail),
        "conv": unsplit(conv_new, conv_tail),
    }
    return x, new_states, kvs


def init_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    (ssd_shape, conv_shape) = M.state_shapes(cfg, batch)
    Lnum = cfg.num_layers
    return {
        "ssd": jnp.zeros((Lnum,) + ssd_shape, jnp.float32),
        "conv": jnp.zeros((Lnum,) + conv_shape, jnp.dtype(cfg.dtype)),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    G, A, rem = _group_counts(cfg)
    st = jax.eval_shape(lambda: init_state(cfg, batch))
    dt = jnp.dtype(cfg.dtype)
    kv_shape = (G, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        **st,
        "kv_k": jax.ShapeDtypeStruct(kv_shape, dt),
        "kv_v": jax.ShapeDtypeStruct(kv_shape, dt),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = params["embed"][tokens]
    x = constrain(x, DATA_AXES, None, None)
    x, _, _ = _run_seq(params, cfg, x, init_state(cfg, B), collect_kv=False)
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = h @ params["unembed"]
    loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"xent": loss}


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array], max_len: int):
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = params["embed"][tokens]
    x = constrain(x, DATA_AXES, None, None)
    x, states, kvs = _run_seq(params, cfg, x, init_state(cfg, B), collect_kv=True)
    h = L.rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = h @ params["unembed"]
    ks, vs = kvs
    pad = max_len - T
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {
        **states,
        "kv_k": ks.astype(jnp.dtype(cfg.dtype)),
        "kv_v": vs.astype(jnp.dtype(cfg.dtype)),
        "lengths": jnp.full((B,), T, jnp.int32),
    }
    return logits, cache


def decode_step(params, cfg: ModelConfig, batch: Dict[str, jax.Array], cache):
    tok = batch["tokens"]
    x = params["embed"][tok]            # (B, D)
    x = constrain(x, DATA_AXES, None)
    G, A, rem = _group_counts(cfg)
    lengths = cache["lengths"]
    sp = params["shared_attn"]

    grouped, tail = _split_groups(params["layers"], G, A)
    ssd_g, ssd_t = _split_groups(cache["ssd"], G, A)
    conv_g, conv_t = _split_groups(cache["conv"], G, A)

    def inner_body(carry, scanned):
        x = carry
        p, sst, cst = scanned
        h = L.rms_norm(x, p["ln"], cfg.norm_eps)
        y, s_new, c_new = M.mamba2_step(p["mamba"], h, cfg, sst, cst)
        return x + y, (s_new, c_new)

    def outer_body(carry, scanned):
        x = carry
        gp, gs, gc, kc, vc = scanned
        x, (s_new, c_new) = jax.lax.scan(inner_body, x, (gp, gs, gc))
        h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
        att, kc2, vc2 = L.attention_decode(sp["attn"], h, cfg, kc, vc, lengths)
        x = x + att
        h = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + L.mlp_block(sp["mlp"], h, cfg)
        return x, (s_new, c_new, kc2, vc2)

    x, (ssd_new, conv_new, ks, vs) = jax.lax.scan(
        outer_body, x, (grouped, ssd_g, conv_g, cache["kv_k"], cache["kv_v"])
    )
    if rem > 0:
        x, (ssd_tail, conv_tail) = jax.lax.scan(inner_body, x, (tail, ssd_t, conv_t))
    else:
        ssd_tail, conv_tail = ssd_t, conv_t

    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = h @ params["unembed"]

    def unsplit(g, t):
        flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), g)
        return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), flat, t)

    new_cache = {
        "ssd": unsplit(ssd_new, ssd_tail),
        "conv": unsplit(conv_new, conv_tail),
        "kv_k": ks,
        "kv_v": vs,
        "lengths": lengths + 1,
    }
    return logits, new_cache
