"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

Faithful structure: token-shift lerps for r/k/v/g, a LoRA tower producing the
per-token data-dependent decay w, per-head bonus u, WKV recurrence (chunked —
same math as kernels/rwkv6_scan.py), per-head group-norm on the WKV output,
and squared-ReLU channel-mix. Decode carries (wkv_state, tmix_shift,
cmix_shift) per layer — constant memory in context length, which is why this
arch runs the long_500k cell.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops, ref
from repro.launch.sharding import DATA_AXES, MODEL_AXIS, constrain
from repro.models import layers as L

LORA_DIM = 64


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    H = D // cfg.rwkv_head_size
    k_emb, k_layers, k_out = jax.random.split(key, 3)

    def init_layer(k):
        ks = jax.random.split(k, 10)
        return {
            "ln1": jnp.ones((D,), dtype),
            "ln2": jnp.ones((D,), dtype),
            # time-mix
            "mu_r": (jnp.zeros((D,), jnp.float32) + 0.5).astype(dtype),
            "mu_k": (jnp.zeros((D,), jnp.float32) + 0.5).astype(dtype),
            "mu_v": (jnp.zeros((D,), jnp.float32) + 0.5).astype(dtype),
            "mu_w": (jnp.zeros((D,), jnp.float32) + 0.5).astype(dtype),
            "mu_g": (jnp.zeros((D,), jnp.float32) + 0.5).astype(dtype),
            "wr": L.dense_init(ks[0], D, D, dtype),
            "wk_t": L.dense_init(ks[1], D, D, dtype),
            "wv_t": L.dense_init(ks[2], D, D, dtype),
            "wg": L.dense_init(ks[3], D, D, dtype),
            "wo_t": L.dense_init(ks[4], D, D, dtype),
            # data-dependent decay LoRA: w = base + tanh(x @ A) @ B
            "w_base": (jnp.full((D,), -0.5, jnp.float32)).astype(dtype),
            "w_lora_a": L.dense_init(ks[5], D, LORA_DIM, dtype),
            "w_lora_b": (jax.random.normal(ks[6], (LORA_DIM, D), jnp.float32) * 0.01).astype(dtype),
            "u": (jax.random.normal(ks[7], (H, cfg.rwkv_head_size), jnp.float32) * 0.1).astype(dtype),
            "ln_x": jnp.ones((D,), dtype),
            # channel-mix
            "mu_cm": (jnp.zeros((D,), jnp.float32) + 0.5).astype(dtype),
            "w_cm_k": L.dense_init(ks[8], D, cfg.d_ff, dtype),
            "w_cm_v": L.dense_init(ks[9], cfg.d_ff, D, dtype),
        }

    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, D, dtype),
        "ln_in": jnp.ones((D,), dtype),
        "layers": jax.vmap(init_layer)(layer_keys),
        "final_norm": jnp.ones((D,), dtype),
        "unembed": L.dense_init(k_out, D, cfg.vocab_size, dtype),
    }


def _tmix_rkvwg(p, x, shifted, cfg: ModelConfig):
    """Compute r, k, v, w, g from token-shift lerps. x/(B,..,D)."""
    D = cfg.d_model
    H = D // cfg.rwkv_head_size
    hs = cfg.rwkv_head_size

    def lerp(mu):
        return x + (shifted - x) * mu

    r = lerp(p["mu_r"]) @ p["wr"]
    k = lerp(p["mu_k"]) @ p["wk_t"]
    v = lerp(p["mu_v"]) @ p["wv_t"]
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["wg"])
    xw = lerp(p["mu_w"])
    w = p["w_base"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    ).astype(jnp.float32)

    def split(t):
        return t.reshape(*t.shape[:-1], H, hs)

    return split(r), split(k), split(v), split(w), g


def _time_mix_seq(p, x, cfg: ModelConfig, state, shift_in):
    """Sequence form. x: (B, T, D); state: (B, H, K, V); shift_in: (B, D).
    Returns (out, new_state, new_shift)."""
    B, T, D = x.shape
    H = D // cfg.rwkv_head_size
    shifted = jnp.concatenate([shift_in[:, None, :], x[:, :-1]], axis=1)
    r, k, v, w, g = _tmix_rkvwg(p, x, shifted, cfg)
    r = constrain(r, DATA_AXES, None, MODEL_AXIS, None)
    k = constrain(k, DATA_AXES, None, MODEL_AXIS, None)
    v = constrain(v, DATA_AXES, None, MODEL_AXIS, None)
    if cfg.attention_impl.startswith("pallas"):
        wkv, s_new = ops.rwkv6_scan(r, k, v, w, p["u"], state, impl=cfg.attention_impl)
    else:
        wkv, s_new = ref.rwkv6_chunked(r, k, v, w.astype(jnp.float32), p["u"], state)
    wkv = wkv.reshape(B, T, D)
    out = (L.group_rms_norm(wkv, p["ln_x"], H) * g) @ p["wo_t"]
    return constrain(out, DATA_AXES, None, None), s_new, x[:, -1]


def _time_mix_step(p, x, cfg: ModelConfig, state, shift_in):
    """Single-token form. x: (B, D)."""
    B, D = x.shape
    H = D // cfg.rwkv_head_size
    r, k, v, w, g = _tmix_rkvwg(p, x, shift_in, cfg)
    wkv, s_new = ref.rwkv6_decode_step(r, k, v, w, p["u"], state)
    wkv = wkv.reshape(B, D)
    out = (L.group_rms_norm(wkv, p["ln_x"], H) * g) @ p["wo_t"]
    return out, s_new, x


def _channel_mix(p, x, shifted):
    lerped = x + (shifted - x) * p["mu_cm"]
    k = jnp.square(jax.nn.relu(lerped @ p["w_cm_k"]))
    return k @ p["w_cm_v"]


def _layer_seq(cfg, p, x, state, shift_t, shift_c):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    att, s_new, new_shift_t = _time_mix_seq(p, h, cfg, state, shift_t)
    x = x + att
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    shifted = jnp.concatenate([shift_c[:, None, :], h[:, :-1]], axis=1)
    x = x + _channel_mix(p, h, shifted)
    return x, s_new, new_shift_t, h[:, -1]


def _run_seq(params, cfg: ModelConfig, x, states):
    """x: (B, T, D) embeddings; states: dict of per-layer carries."""

    def body(carry, scanned):
        x = carry
        p, st, sh_t, sh_c = scanned

        fwd = functools.partial(_layer_seq, cfg)
        if cfg.remat:
            fwd = jax.checkpoint(fwd)
        x, s_new, nsh_t, nsh_c = fwd(p, x, st, sh_t, sh_c)
        return x, (s_new, nsh_t, nsh_c)

    x, (s_all, sht_all, shc_all) = jax.lax.scan(
        body, x, (params["layers"], states["wkv"], states["shift_t"], states["shift_c"])
    )
    return x, {"wkv": s_all, "shift_t": sht_all, "shift_c": shc_all,
               "lengths": states["lengths"] + x.shape[1]}


def init_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    D = cfg.d_model
    H = D // cfg.rwkv_head_size
    hs = cfg.rwkv_head_size
    f32 = jnp.float32
    return {
        "wkv": jnp.zeros((cfg.num_layers, batch, H, hs, hs), f32),
        "shift_t": jnp.zeros((cfg.num_layers, batch, D), jnp.dtype(cfg.dtype)),
        "shift_c": jnp.zeros((cfg.num_layers, batch, D), jnp.dtype(cfg.dtype)),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """State stands in for the KV cache; size is O(1) in max_len."""
    return jax.eval_shape(lambda: init_state(cfg, batch))


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = L.rms_norm(params["embed"][tokens], params["ln_in"], cfg.norm_eps)
    x = constrain(x, DATA_AXES, None, None)
    x, _ = _run_seq(params, cfg, x, init_state(cfg, B))
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = h @ params["unembed"]
    loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"xent": loss}


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array], max_len: int):
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = L.rms_norm(params["embed"][tokens], params["ln_in"], cfg.norm_eps)
    x = constrain(x, DATA_AXES, None, None)
    x, state = _run_seq(params, cfg, x, init_state(cfg, B))
    h = L.rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    return h @ params["unembed"], state


def decode_step(params, cfg: ModelConfig, batch: Dict[str, jax.Array], cache):
    tok = batch["tokens"]
    x = L.rms_norm(params["embed"][tok], params["ln_in"], cfg.norm_eps)

    def body(carry, scanned):
        x = carry
        p, st, sh_t, sh_c = scanned
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        att, s_new, nsh_t = _time_mix_step(p, h, cfg, st, sh_t)
        x = x + att
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + _channel_mix(p, h, sh_c)
        return x, (s_new, nsh_t, h)

    x, (s_all, sht, shc) = jax.lax.scan(
        body, x, (params["layers"], cache["wkv"], cache["shift_t"], cache["shift_c"])
    )
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = h @ params["unembed"]
    new_cache = {"wkv": s_all, "shift_t": sht, "shift_c": shc,
                 "lengths": cache["lengths"] + 1}
    return logits, new_cache
