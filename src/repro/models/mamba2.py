"""Mamba2 block (SSD) — used by the Zamba2 hybrid.

in_proj -> [z | x | B | C | dt]; causal depthwise conv over [x|B|C];
SSD recurrence (chunked, same math as kernels/mamba2_ssd.py); gated RMSNorm;
out_proj. Decode carries (conv_state, ssd_state).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops, ref
from repro.launch.sharding import DATA_AXES, MODEL_AXIS, constrain
from repro.models import layers as L


def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(d_inner, num_heads, head_dim, conv_dim)."""
    Din = cfg.d_inner
    P = cfg.ssm_head_dim
    H = Din // P
    conv_dim = Din + 2 * cfg.ssm_state_dim
    return Din, H, P, conv_dim


def mamba2_init(key, cfg: ModelConfig, dtype) -> Dict[str, jax.Array]:
    D = cfg.d_model
    Din, H, P, conv_dim = mamba2_dims(cfg)
    N = cfg.ssm_state_dim
    ks = jax.random.split(key, 4)
    d_proj = 2 * Din + 2 * N + H  # z, x, B, C, dt
    return {
        "w_in": L.dense_init(ks[0], D, d_proj, dtype),
        "w_out": L.dense_init(ks[1], Din, D, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv_width, conv_dim), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm_g": jnp.ones((Din,), dtype),
    }


def _split_proj(proj, cfg: ModelConfig):
    Din, H, P, _ = mamba2_dims(cfg)
    N = cfg.ssm_state_dim
    z = proj[..., :Din]
    xbc = proj[..., Din:Din + Din + 2 * N]
    dt = proj[..., Din + Din + 2 * N:]
    return z, xbc, dt


def _causal_conv_seq(xbc, conv_w, conv_b, conv_state):
    """xbc: (B, T, C); conv_state: (B, W-1, C) carried from previous tokens."""
    W = conv_w.shape[0]
    full = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(W):
        out = out + full[:, i:i + xbc.shape[1]] * conv_w[i]
    new_state = full[:, -(W - 1):] if W > 1 else conv_state
    return jax.nn.silu(out + conv_b), new_state


def _causal_conv_step(xbc, conv_w, conv_b, conv_state):
    """xbc: (B, C) single token."""
    W = conv_w.shape[0]
    window = jnp.concatenate([conv_state.astype(xbc.dtype), xbc[:, None]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window, conv_w.astype(xbc.dtype)) + conv_b
    return jax.nn.silu(out), window[:, 1:]


def mamba2_seq(p, x, cfg: ModelConfig, ssd_state, conv_state):
    """x: (B, T, D). Returns (out, new_ssd_state, new_conv_state)."""
    B, T, D = x.shape
    Din, H, P, conv_dim = mamba2_dims(cfg)
    N = cfg.ssm_state_dim
    proj = x @ p["w_in"]
    proj = constrain(proj, DATA_AXES, None, MODEL_AXIS)
    z, xbc, dt = _split_proj(proj, cfg)
    xbc, new_conv = _causal_conv_seq(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :Din].reshape(B, T, H, P)
    Bm = xbc[..., Din:Din + N]
    C = xbc[..., Din + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,T,H)
    A = -jnp.exp(p["A_log"])                                           # (H,)
    if cfg.attention_impl.startswith("pallas"):
        y, s_new = ops.mamba2_ssd(xs, dt, A, Bm, C, ssd_state, impl=cfg.attention_impl)
    else:
        y, s_new = ref.mamba2_ssd_chunked(xs, dt, A, Bm, C, ssd_state)
    y = y + xs * p["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, T, Din)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_g"], cfg.norm_eps)
    out = y @ p["w_out"]
    return constrain(out, DATA_AXES, None, None), s_new, new_conv


def mamba2_step(p, x, cfg: ModelConfig, ssd_state, conv_state):
    """x: (B, D) single token."""
    B, D = x.shape
    Din, H, P, conv_dim = mamba2_dims(cfg)
    N = cfg.ssm_state_dim
    proj = x @ p["w_in"]
    z, xbc, dt = _split_proj(proj, cfg)
    xbc, new_conv = _causal_conv_step(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :Din].reshape(B, H, P)
    Bm = xbc[..., Din:Din + N]
    C = xbc[..., Din + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,H)
    A = -jnp.exp(p["A_log"])
    y, s_new = ref.mamba2_decode_step(xs, dt, A, Bm, C, ssd_state)
    y = y + xs * p["D_skip"].astype(y.dtype)[None, :, None]
    y = y.reshape(B, Din)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_g"], cfg.norm_eps)
    return y @ p["w_out"], s_new, new_conv


def state_shapes(cfg: ModelConfig, batch: int):
    Din, H, P, conv_dim = mamba2_dims(cfg)
    N = cfg.ssm_state_dim
    W = cfg.ssm_conv_width
    return (
        (batch, H, P, N),          # ssd state (fp32)
        (batch, W - 1, conv_dim),  # conv state
    )
