"""Config system for the MemForest framework.

Plain dataclasses — no external config dependency. Every architecture in
``repro.configs`` produces a :class:`ModelConfig`; shapes produce a
:class:`ShapeConfig`; the launcher combines them with a :class:`MeshConfig`.

Configs are immutable (frozen) so they can be closed over by jitted functions
and used as cache keys.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` selects the block type:
      * ``dense``  — pre-norm GQA transformer (RoPE, SwiGLU or GeLU MLP)
      * ``moe``    — dense attention + top-k routed expert MLP
      * ``ssm``    — RWKV6 (attention-free, data-dependent decay)
      * ``hybrid`` — Zamba2: Mamba2 backbone + shared attention block
      * ``encdec`` — Whisper-style encoder-decoder (frame-embedding frontend stub)
      * ``vlm``    — Pixtral-style decoder with patch-embedding stub
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state_dim: int = 0          # Mamba2 N (state size per head)
    ssm_head_dim: int = 64          # Mamba2 P (channels per head)
    ssm_expand: int = 2             # d_inner = expand * d_model
    ssm_conv_width: int = 4
    attn_every: int = 0             # hybrid: shared attention every k blocks
    rwkv_head_size: int = 64

    # --- enc-dec / vlm frontends (stubs provide embeddings directly) ---
    encoder_layers: int = 0
    encoder_seq_len: int = 1500     # whisper audio frames after conv stub
    num_patches: int = 64           # pixtral patch embeddings prepended

    # --- positional / numerics ---
    rope_theta: float = 500000.0
    max_seq_len: int = 32768
    norm_eps: float = 1e-5
    mlp_activation: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # --- execution ---
    attention_impl: str = "reference"   # reference | pallas | pallas_interpret
    scan_layers: bool = True
    remat: bool = True
    logits_softcap: float = 0.0
    # MoE expert-weight FSDP (shard dim-1 over the data axes). Required to
    # fit 235B training; DISABLE for serving (pure EP) — otherwise every
    # decode step all-gathers the expert weights (EXPERIMENTS.md §Perf).
    moe_fsdp_params: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads {self.num_heads} not divisible by "
            f"num_kv_heads {self.num_kv_heads}"
        )

    # ---- derived quantities ---------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs that run the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (used for 6ND roofline accounting)."""
        V, D, L, F = self.vocab_size, self.d_model, self.num_layers, self.d_ff
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            mlp = 3 * D * F if self.mlp_activation == "swiglu" else 2 * D * F
            per_layer = attn + mlp + 2 * D
            return emb + L * per_layer + D
        if self.family == "moe":
            attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            n_e = self.experts_per_token if active_only else self.num_experts
            mlp = 3 * D * F * n_e + D * self.num_experts  # experts + router
            per_layer = attn + mlp + 2 * D
            return emb + L * per_layer + D
        if self.family == "ssm":  # rwkv6
            H = D // self.rwkv_head_size
            tmix = 4 * D * D + D * D  # r,k,v,o + gate
            decay_lora = 2 * D * 64 + 5 * D * 32  # w lora + ddlerp towers
            cmix = 2 * D * self.d_ff_rwkv
            per_layer = tmix + decay_lora + cmix + 4 * D + H * self.rwkv_head_size
            return emb + L * per_layer + 2 * D
        if self.family == "hybrid":  # zamba2
            Din, N = self.d_inner, self.ssm_state_dim
            H = Din // self.ssm_head_dim
            in_proj = D * (2 * Din + 2 * H * N + H)
            out_proj = Din * D
            conv = self.ssm_conv_width * (Din + 2 * H * N)
            per_mamba = in_proj + out_proj + conv + 2 * H + Din + 2 * D
            attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            shared_mlp = 3 * D * self.d_ff
            n_attn_apps = self.num_layers // max(self.attn_every, 1)
            shared = attn + shared_mlp + 2 * D  # one set of shared weights
            return emb + L * per_mamba + shared + D + n_attn_apps * 2 * D
        if self.family == "encdec":
            attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            mlp = 2 * D * F  # gelu
            enc = self.encoder_layers * (attn + mlp + 2 * D)
            dec = L * (2 * attn + mlp + 3 * D)  # self + cross attn
            return emb + enc + dec + 2 * D
        raise ValueError(self.family)

    @property
    def d_ff_rwkv(self) -> int:
        return self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: the input shape and which step it lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


@dataclass(frozen=True)
class MeshConfig:
    """Production mesh description (see launch/mesh.py)."""

    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    multi_pod: bool = False

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axis_names if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatch_size: int = 0          # 0 = no microbatching
    zero1: bool = True                # shard optimizer states over data axes
    grad_compression: str = "none"    # none | topk | int8
    compression_ratio: float = 0.125  # for topk
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


@dataclass(frozen=True)
class MemForestConfig:
    """Paper defaults (Sections 4, 6; Appendix C)."""

    chunk_turns: int = 2            # b = 2 (Appendix C operating point)
    branching_factor: int = 8       # k; Fig. 6d/e knee is moderate (<=16)
    embed_dim: int = 256
    canonical_sim_threshold: float = 0.92
    scene_sim_threshold: float = 0.60
    forest_recall_topk: int = 8     # trees recalled per query
    fact_recall_topk: int = 16      # facts for fact->tree recall
    final_topk: int = 10            # paper: final retrieval budget top-10
    browse_beam: int = 2            # children expanded per level
    browse_mode: str = "llm+planner"  # flat | root-only | emb | emb+planner | llm | llm+planner
    tree_families: Tuple[str, ...] = ("entity", "scene", "session")
    lazy_refresh: bool = True
    level_parallel: bool = True
    # defer the dirty-path flush past ingestion entirely: summaries refresh
    # on the first query that needs them (LSM-style read-triggered
    # compaction). Minimizes write latency; first-read pays the flush.
    read_triggered_refresh: bool = False
    max_nodes_per_tree: int = 4096
    encoder: str = "hashing"        # hashing | model
