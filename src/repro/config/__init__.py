from repro.config.base import (
    ModelConfig,
    MeshConfig,
    TrainConfig,
    MemForestConfig,
    ShapeConfig,
)

__all__ = [
    "ModelConfig",
    "MeshConfig",
    "TrainConfig",
    "MemForestConfig",
    "ShapeConfig",
]
