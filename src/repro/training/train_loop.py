"""Train-step builder: loss -> grads (optional microbatch accumulation) ->
optional compression -> clip -> AdamW. One function, jitted once, lowered by
the dry-run for every architecture.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models.factory import Model
from repro.training import grad_compress, optimizer


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "err"?}. Microbatching splits the batch along
    dim 0 into tcfg.microbatch_size-sized slices accumulated with lax.scan
    (keeps peak activation memory at one-microbatch scale)."""

    use_compress = tcfg.grad_compression != "none"

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.microbatch_size and tcfg.microbatch_size > 0:
            some = jax.tree.leaves(batch)[0]
            B = some.shape[0]
            mb = tcfg.microbatch_size
            n = B // mb
            assert n * mb == B, (B, mb)
            from repro.launch.sharding import DATA_AXES, constrain
            resh = jax.tree.map(
                lambda x: constrain(
                    x.reshape((n, mb) + x.shape[1:]),
                    None, DATA_AXES, *([None] * (x.ndim - 1)),
                ),
                batch,
            )

            def body(carry, mbatch):
                acc, loss_acc = carry
                (loss, metrics), g = grad_fn(params, mbatch)
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + loss), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, loss_sum), _ = jax.lax.scan(body, (zero, 0.0), resh)
            g = jax.tree.map(lambda x: x / n, g)
            return loss_sum / n, g
        (loss, metrics), g = grad_fn(params, batch)
        return loss, g

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params = state["params"]
        loss, grads = compute_grads(params, batch)
        metrics: Dict[str, jax.Array] = {"loss": loss}
        if use_compress:
            grads, new_err, cm = grad_compress.compress(
                grads, state["err"],
                method=tcfg.grad_compression, ratio=tcfg.compression_ratio,
            )
            metrics.update(cm)
        grads, gnorm = optimizer.clip_by_global_norm(grads, tcfg.grad_clip)
        metrics["grad_norm"] = gnorm
        new_params, new_opt, om = optimizer.adamw_update(params, grads, state["opt"], tcfg)
        metrics.update(om)
        new_state = {"params": new_params, "opt": new_opt}
        if use_compress:
            new_state["err"] = new_err
        return new_state, metrics

    return train_step


def init_train_state(model: Model, tcfg: TrainConfig, key) -> Dict[str, Any]:
    params = model.init(key)
    state = {"params": params, "opt": optimizer.init_opt_state(params)}
    if tcfg.grad_compression != "none":
        state["err"] = grad_compress.init_error_state(params)
    return state


def train_state_specs(model: Model, tcfg: TrainConfig):
    """ShapeDtypeStruct pytree of the train state — dry-run, no allocation."""
    return jax.eval_shape(functools.partial(init_train_state, model, tcfg),
                          jax.random.key(0))
