"""AdamW with warmup+cosine schedule. Sharding-agnostic: ZeRO-1 placement of
the moments is applied at jit boundary via launch.sharding.zero1_shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(step: jax.Array, cfg: TrainConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cosine)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: TrainConfig) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = lr_schedule(step, cfg)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay

    grads32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads32)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads32)
    t = step.astype(jnp.float32)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)

    def upd(p, m, v):
        u = (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps)
        u = u + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {"lr": lr}
