"""Gradient compression for cross-pod data parallelism.

At 512+ chips the DP all-reduce crosses the pod boundary (DCI — an order of
magnitude less bandwidth than intra-pod ICI). Two compressors:

  * topk   — per-tensor magnitude top-k sparsification with ERROR FEEDBACK
             (residual accumulates, nothing is lost in expectation). A real
             deployment all-gathers (indices, values): volume = 2 * ratio of
             dense. Here the math (and convergence behavior) is exact; the
             collective itself stays dense under SPMD — the byte saving is
             accounted analytically in EXPERIMENTS.md §Roofline.
  * int8   — per-tensor symmetric quantization (2x vs bf16, 4x vs fp32).

Both run INSIDE the train step (jitted), before the gradient psum that the
data-parallel sharding induces.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_one(g: jax.Array, ratio: float) -> jax.Array:
    flat = g.reshape(-1)
    k = max(int(flat.shape[0] * ratio), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    return (flat * mask).reshape(g.shape)


def _int8_one(g: jax.Array) -> jax.Array:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress(grads: Any, err: Any, *, method: str, ratio: float = 0.125
             ) -> Tuple[Any, Any, Dict[str, jax.Array]]:
    """Returns (compressed_grads, new_error_state, metrics)."""
    if method == "none":
        return grads, err, {"compress_ratio": jnp.asarray(1.0)}
    g32 = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, err)
    if method == "topk":
        comp = jax.tree.map(lambda g: _topk_one(g, ratio), g32)
        new_err = jax.tree.map(lambda g, c: g - c, g32, comp)
        # wire volume: indices (4B) + values (4B) per kept entry vs 2B dense
        wire = jnp.asarray(ratio * (4 + 4) / 2.0)
        return comp, new_err, {"compress_ratio": wire}
    if method == "int8":
        comp = jax.tree.map(_int8_one, g32)
        new_err = jax.tree.map(lambda g, c: g - c, g32, comp)
        return comp, new_err, {"compress_ratio": jnp.asarray(0.5)}
    raise ValueError(method)
