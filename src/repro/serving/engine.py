"""Batched serving engine: continuous batching + shared-prefix KV reuse.

This is the layer MemForest's write path runs on in production: chunk
extraction calls share a long prompt prefix (the extraction instruction), so
the engine computes that prefix KV ONCE per batch shape and broadcasts it
across slots — the paper's §5.2 note that "much of this overhead is repeated
prompt prefixes and can be amortized by prefix caching", realized.

Continuous batching: fixed slot array; finished sequences are evicted and
queued requests admitted between decode steps, so occupancy stays high under
ragged output lengths.

Ingest lane: when the engine is built with a memory system, whole-session
write requests queue alongside decode traffic and drain between decode steps
as ONE ``ingest_batch`` call per engine iteration — write traffic rides the
same continuous-batching loop, so concurrent tenants' sessions share encoder
forwards and tree_refresh launches (core/ingest.py).

Query lane: the read-path mirror of the ingest lane. Retrieval requests
queued via ``submit_query`` drain between decode steps as ONE
``query_batch`` call per engine iteration, so concurrent tenants' queries
share the encoder forward, the fused topk_sim index scans, and the
level-synchronous browse launches (core/retrieval.py). Decode, ingest, and
query traffic all ride the same continuous-batching loop.

Multi-device serve: pass ``sharded=ShardedServeConfig(devices=N)`` to shard
the memory system's serve path over a 1-D data mesh
(``launch.mesh.make_data_mesh`` + ``MemForestSystem.set_mesh``): fact-index
rows round-robin across devices with shard-local top-k + candidate merge,
browse lanes and flush refresh batches data-parallel, roots replicated.
Results are exactly identical to single-device serve (kernels/shard_ops.py);
with <2 devices the config degrades to the mesh=None fast path.

Maintenance lane: when built with a ``maintenance`` plane
(core/maintenance_plane.py), ingest drains stop flushing inline
(``defer_flush=True``) and the engine instead runs a bounded slice of
maintenance work — summary refresh, compaction, queued merges — per step.
Flushes no longer block the ingest or query drains; they interleave with
the decode cadence (or run on the plane's background thread).

Residency lane: pass ``residency=ResidencyManager(...)``
(core/residency.py) and ``submit_session``/``submit_query`` accept a
``tenant=`` id routed through the hot/cold tier — cold tenants rehydrate
transparently inside the drains (queries may answer from the always-
resident digest instead), and budget enforcement (demotion = snapshot +
device-cache free) runs as its own bounded drain after the maintenance
lane, so eviction work never sits on a decode step. ``tenant=None``
requests keep using the engine's single ``memory`` system unchanged.

Observability: pass ``obs=Observability(...)`` (repro/obs) — or rely on the
per-engine default — and every step phase (admit, prefill, decode, the
ingest/query/maintenance/residency drains) runs under a span; the legacy
counter set lives in the ``serve/*`` registry namespace and per-request
queue-to-done waits stream into ``serve/{ingest,query}_wait_s`` histograms.
Tracing is off by default (span sites cost one boolean check);
``repro.obs.enable_tracing(sink)`` lights up the whole process.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import HashTokenizer
from repro.models.factory import Model
from repro.obs import Observability, get_obs


@dataclass
class Request:
    req_id: int
    prompt_tokens: List[int]
    max_new_tokens: int = 8
    prefix_key: Optional[str] = None    # shared-prefix cache key
    out_tokens: List[int] = field(default_factory=list)
    submitted_s: float = 0.0
    finished_s: float = 0.0


class PrefixCache:
    """Prefill reuse cache for shared prompt prefixes.

    Granularity: one entry per (prefix_key, padded admission signature) —
    the prefill of a whole right-aligned token block. Prefill is a pure
    function of the padded token matrix, so when an admission with the same
    prefix_key reproduces the same block (the common serving pattern:
    repeated instruction-prefix prompts landing in freed slots), the cached
    (logits, KV) are reused and the prefill launch is skipped entirely.
    Finer prefix-segment reuse (prefix KV + suffix-only prefill) needs a
    position-offset prefill in the model API — ROADMAP open item.

    Each entry pins a full-width prefill (logits + KV tree) on device, so
    ``max_entries`` bounds the pinned footprint at max_entries x one engine
    cache; eviction is FIFO."""

    def __init__(self, max_entries: int = 4):
        self.entries: Dict[Tuple, Tuple] = {}
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def get(self, key: str, sig: Tuple):
        e = self.entries.get((key, sig))
        if e is not None:
            self.hits += 1
            return e
        self.misses += 1
        return None

    def put(self, key: str, sig: Tuple, logits, cache) -> None:
        if len(self.entries) >= self.max_entries:
            self.entries.pop(next(iter(self.entries)))
        self.entries[(key, sig)] = (logits, cache)


@dataclass(frozen=True)
class ShardedServeConfig:
    """Multi-device serve knobs. ``devices=0`` means all local devices;
    anything that resolves to <2 devices falls back to single-device."""
    devices: int = 0
    axis: str = "data"


class ServeEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_len: int = 512, eos_id: int = 2,
                 memory=None, max_ingest_batch: int = 16,
                 max_query_batch: int = 32,
                 maintenance=None, maintenance_budget: int = 1,
                 sharded: Optional[ShardedServeConfig] = None,
                 residency=None, residency_budget: int = 1,
                 obs: Optional[Observability] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * max_batch
        self.finished: List[Request] = []
        self.cache = None
        self.prefix_cache = PrefixCache()
        self._next_id = 0
        # observability: every legacy counter below now lives in the
        # registry (serve/* namespace) and is read back through a property,
        # so engine.metrics() reports through the registry while attribute
        # access (engine.ingest_sessions, ...) keeps working. Span sites
        # (engine.step phases) go through self.obs.span and cost one bool
        # check while tracing is disabled.
        self.obs = get_obs(obs)
        reg = self.obs.registry
        self._m_steps = reg.counter("serve/decode_steps")
        self._m_decoded = reg.counter("serve/decoded_tokens")
        self._m_occupancy = reg.counter("serve/occupancy_sum")
        self._m_prefills = reg.counter("serve/prefills")
        self._m_prefills_reused = reg.counter("serve/prefills_reused")
        self._m_ingest_batches = reg.counter("serve/ingest_batches")
        self._m_ingest_sessions = reg.counter("serve/ingest_sessions")
        self._m_query_batches = reg.counter("serve/query_batches")
        self._m_queries_served = reg.counter("serve/queries_served")
        self._m_maintenance_turns = reg.counter("serve/maintenance_turns")
        self._m_residency_turns = reg.counter("serve/residency_turns")
        # per-request queue-to-done latency distributions (always on —
        # these are metrics, not traces; a record is ~100ns)
        self._h_ingest_wait = reg.histogram("serve/ingest_wait_s")
        self._h_query_wait = reg.histogram("serve/query_wait_s")
        self._h_decode_request = reg.histogram("serve/decode_request_s")
        # ingest-request lane: write traffic (whole sessions bound for the
        # memory substrate) rides the same engine loop as decode slots —
        # everything queued between two engine steps drains as ONE
        # MemForestSystem.ingest_batch call (cross-tenant write batching)
        self.memory = memory
        # multi-device serve: attach a data mesh to the memory system so the
        # ingest/query drains below run the sharded serve path transparently
        self.serve_mesh = None
        if sharded is not None and memory is not None:
            from repro.launch.mesh import make_data_mesh

            self.serve_mesh = make_data_mesh(sharded.devices, sharded.axis)
            memory.set_mesh(self.serve_mesh, sharded.axis)
        self.max_ingest_batch = max_ingest_batch
        self.ingest_queue: List = []
        # query-request lane: read traffic mirrors the ingest lane —
        # everything queued between two engine steps drains as ONE
        # MemForestSystem.query_batch call (cross-tenant read batching)
        self.max_query_batch = max_query_batch
        self.query_queue: List = []
        self.query_results: Dict[int, object] = {}
        # maintenance lane: with a plane attached, ingest drains defer their
        # flush and the engine drains `maintenance_budget` units of refresh/
        # compaction/merge work per step instead. The plane's lock guards
        # forest access when its background thread is running.
        self.maintenance = maintenance
        self.maintenance_budget = maintenance_budget
        # residency lane: multi-tenant hot/cold tier. The engine owns budget
        # enforcement (auto_enforce off): demotions drain at most
        # ``residency_budget`` per step AFTER the serve lanes, so eviction
        # (snapshot + device free) never blocks a decode step.
        self.residency = residency
        self.residency_budget = residency_budget
        if residency is not None:
            residency.auto_enforce = False

        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len)
        )
        self._decode = jax.jit(model.decode)

    # ------------------------------------------------------------------
    # registry-backed legacy counters (attribute back-compat)
    # ------------------------------------------------------------------
    @property
    def steps(self) -> int:
        return self._m_steps.value

    @property
    def decoded_tokens(self) -> int:
        return self._m_decoded.value

    @property
    def occupancy_sum(self) -> float:
        return self._m_occupancy.value

    @property
    def prefills(self) -> int:
        return self._m_prefills.value

    @property
    def prefills_reused(self) -> int:
        return self._m_prefills_reused.value

    @property
    def ingest_batches(self) -> int:
        return self._m_ingest_batches.value

    @property
    def ingest_sessions(self) -> int:
        return self._m_ingest_sessions.value

    @property
    def query_batches(self) -> int:
        return self._m_query_batches.value

    @property
    def queries_served(self) -> int:
        return self._m_queries_served.value

    @property
    def maintenance_turns(self) -> int:
        return self._m_maintenance_turns.value

    @property
    def residency_turns(self) -> int:
        return self._m_residency_turns.value

    # ------------------------------------------------------------------
    def submit(self, prompt_tokens: List[int], max_new_tokens: int = 8,
               prefix_key: Optional[str] = None) -> int:
        r = Request(self._next_id, list(prompt_tokens), max_new_tokens,
                    prefix_key, submitted_s=time.perf_counter())
        self._next_id += 1
        self.queue.append(r)
        return r.req_id

    def submit_session(self, session, *, tenant: Optional[str] = None) -> None:
        """Queue a session for the ingest lane. ``tenant`` routes the write
        through the residency tier (rehydrating a cold tenant on drain);
        None targets the engine's single memory system."""
        if tenant is not None:
            if self.residency is None:
                raise RuntimeError(
                    "tenant= requires a ResidencyManager (residency=)")
        elif self.memory is None:
            raise RuntimeError("ServeEngine was built without a memory system")
        self.ingest_queue.append((tenant, session, time.perf_counter()))

    def _memory_lock(self):
        """Forest-access guard: the maintenance plane's lock when one is
        attached (its background worker may be mutating derived state), a
        no-op otherwise."""
        if self.maintenance is not None:
            return self.maintenance.lock
        return contextlib.nullcontext()

    def _drain_ingest(self) -> int:
        """One ingest-lane turn: everything queued (capped) goes through a
        single batched write per destination — the shared memory system, or
        one ``ResidencyManager.ingest`` per tenant (cold tenants rehydrate
        here, inside the drain, not on the submit path). With a maintenance
        plane attached the shared-system flush is deferred to the plane.
        Returns sessions ingested."""
        if not self.ingest_queue:
            return 0
        batch = self.ingest_queue[: self.max_ingest_batch]
        del self.ingest_queue[: len(batch)]
        with self.obs.span("engine.drain.ingest", sessions=len(batch)):
            groups: Dict[Optional[str], List] = {}
            for tenant, session, _t in batch:
                groups.setdefault(tenant, []).append(session)
            for tenant, sessions in groups.items():
                if tenant is not None:
                    self.residency.ingest(tenant, sessions)
                    self._m_ingest_batches.inc()
                    continue
                with self._memory_lock():
                    if self.maintenance is not None:
                        self.memory.ingest_batch(sessions, defer_flush=True)
                    else:
                        self.memory.ingest_batch(sessions)
                self._m_ingest_batches.inc()
        now = time.perf_counter()
        for _tenant, _session, t in batch:
            self._h_ingest_wait.record(now - t)
        self._m_ingest_sessions.inc(len(batch))
        return len(batch)

    def submit_query(self, query, *, mode: Optional[str] = None,
                     final_topk: Optional[int] = None,
                     tenant: Optional[str] = None) -> int:
        """Queue a retrieval request for the query lane. ``tenant`` routes
        through the residency tier (digest answer or rehydrate on drain);
        None targets the engine's single memory system. The result lands in
        ``query_results[req_id]`` after the engine step that drains it."""
        if tenant is not None:
            if self.residency is None:
                raise RuntimeError(
                    "tenant= requires a ResidencyManager (residency=)")
        elif self.memory is None:
            raise RuntimeError("ServeEngine was built without a memory system")
        rid = self._next_id
        self._next_id += 1
        self.query_queue.append((rid, tenant, query, mode, final_topk,
                                 time.perf_counter()))
        return rid

    def pop_query_result(self, req_id: int):
        """Consume a finished query's result (None if not served yet).
        Long-lived deployments must consume results — ``query_results``
        holds everything unconsumed, like ``finished`` does for decodes."""
        return self.query_results.pop(req_id, None)

    def _drain_queries(self) -> int:
        """One query-lane turn: everything queued (capped) goes through
        batched retrieval — one ``query_batch`` per distinct (tenant, mode,
        topk) group, usually exactly one. Tenant groups run through the
        residency tier (digest gate / rehydration happen here, inside the
        drain). Returns queries answered."""
        if not self.query_queue:
            return 0
        batch = self.query_queue[: self.max_query_batch]
        del self.query_queue[: len(batch)]
        with self.obs.span("engine.drain.query", queries=len(batch)):
            groups: Dict[Tuple, List] = {}
            for rid, tenant, q, mode, topk, _t in batch:
                groups.setdefault((tenant, mode, topk), []).append((rid, q))
            for (tenant, mode, topk), items in groups.items():
                if tenant is not None:
                    res = self.residency.query_batch(
                        tenant, [q for _, q in items], mode=mode,
                        final_topk=topk)
                else:
                    with self._memory_lock():
                        res = self.memory.query_batch(
                            [q for _, q in items], mode=mode, final_topk=topk)
                for (rid, _q), r in zip(items, res):
                    self.query_results[rid] = r
                self._m_query_batches.inc()
        now = time.perf_counter()
        for rec in batch:
            self._h_query_wait.record(now - rec[5])
        self._m_queries_served.inc(len(batch))
        return len(batch)

    # ------------------------------------------------------------------
    def _admit(self) -> List[Request]:
        """Fill free slots from the queue. New slots are prefilled as a
        full-width batch (static shapes) and their cache rows SCATTERED into
        the live cache — active decodes are untouched (continuous batching).
        """
        free = [i for i, a in enumerate(self.active) if a is None]
        if not free or not self.queue:
            return []
        admitted_slots: List[int] = []
        for i in free:
            if not self.queue:
                break
            self.active[i] = self.queue.pop(0)
            admitted_slots.append(i)

        B = self.max_batch
        prompts = [
            (self.active[i].prompt_tokens if self.active[i] is not None and i in admitted_slots
             else [0])
            for i in range(B)
        ]
        L = max(max(len(p) for p in prompts), 2)
        toks = np.zeros((B, L), np.int32)
        for i in admitted_slots:
            p = prompts[i]
            toks[i, L - len(p):] = p          # right-align
        # prefill reuse: when every admitted request carries the same
        # prefix_key and this admission reproduces a cached padded token
        # block, the prefill launch is skipped (prefill is a pure function
        # of the block). jax arrays are immutable and the cache merge below
        # is functional, so reuse is aliasing-safe.
        pkeys = {self.active[i].prefix_key for i in admitted_slots}
        pkey = pkeys.pop() if len(pkeys) == 1 else None
        sig = (tuple(admitted_slots), toks.tobytes()) if pkey is not None else None
        hit = self.prefix_cache.get(pkey, sig) if pkey is not None else None
        self._m_prefills.inc()
        if hit is not None:
            logits, new_cache = hit
            self._m_prefills_reused.inc()
        else:
            with self.obs.span("engine.prefill", slots=len(admitted_slots),
                               width=int(L)):
                logits, new_cache = self._prefill(
                    self.params, {"tokens": jnp.asarray(toks)})
            if pkey is not None:
                self.prefix_cache.put(pkey, sig, logits, new_cache)

        if self.cache is None:
            self.cache = new_cache
            self._last_logits = logits
        else:
            slots = jnp.asarray(admitted_slots, jnp.int32)

            def merge(old, new):
                if old.ndim >= 2 and old.shape[0] == self.model.cfg.num_layers \
                        and old.shape[1] == B:
                    return old.at[:, slots].set(new[:, slots])
                if old.ndim >= 1 and old.shape[0] == B:
                    return old.at[slots].set(new[slots])
                return old
            self.cache = jax.tree.map(merge, self.cache, new_cache)
            self._last_logits = self._last_logits.at[slots].set(logits[slots])
        return [self.active[i] for i in admitted_slots]

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit + one decode step for all active,
        then one ingest-lane and one query-lane drain. Returns number of
        finished decode requests. Every phase (admit incl. prefill, decode,
        the four drains) runs under its own span, so enabling tracing yields
        a per-phase latency distribution (``span/engine.*`` histograms)."""
        with self.obs.span("engine.step"):
            with self.obs.span("engine.admit"):
                self._admit()
            act = [a for a in self.active if a is not None]
            if not act:
                self._drain_ingest()
                self._drain_queries()
                self._drain_maintenance()
                self._drain_residency()
                return 0
            self._m_occupancy.inc(len(act) / self.max_batch)
            self._m_steps.inc()

            with self.obs.span("engine.decode", lanes=len(act)):
                # greedy next token from last logits
                # the one sanctioned sync: greedy sampling must read the
                # token ids before Python can append them to lane buffers
                # memlint: ignore[host-sync]
                next_tok = np.asarray(jnp.argmax(self._last_logits, axis=-1))
                for i, a in enumerate(self.active):
                    if a is None:
                        continue
                    a.out_tokens.append(int(next_tok[i]))
                    self._m_decoded.inc()
                batch = {"tokens": jnp.asarray(next_tok.astype(np.int32))}
                self._last_logits, self.cache = self._decode(
                    self.params, batch, self.cache)

            finished = 0
            for i, a in enumerate(self.active):
                if a is None:
                    continue
                if len(a.out_tokens) >= a.max_new_tokens or a.out_tokens[-1] == self.eos_id:
                    a.finished_s = time.perf_counter()
                    self._h_decode_request.record(a.finished_s - a.submitted_s)
                    self.finished.append(a)
                    self.active[i] = None
                    finished += 1
            self._drain_ingest()
            self._drain_queries()
            self._drain_maintenance()
            self._drain_residency()
            return finished

    def _drain_maintenance(self) -> int:
        """One maintenance-lane turn: a bounded slice of refresh/compaction/
        merge work (no-op when the plane runs its own background thread with
        budget 0, or when no plane is attached)."""
        if self.maintenance is None or self.maintenance_budget <= 0:
            return 0
        if self.maintenance.pending() == 0:
            return 0
        with self.obs.span("engine.drain.maintenance"):
            done = self.maintenance.run_some(self.maintenance_budget)["units"]
        if done:
            self._m_maintenance_turns.inc()
        return done

    def _drain_residency(self) -> int:
        """One residency-lane turn: demote at most ``residency_budget``
        over-budget tenants (snapshot + device-cache free). Bounded per
        step, so eviction interleaves with the decode cadence instead of
        blocking it — the residency twin of the maintenance drain."""
        if self.residency is None or self.residency_budget <= 0:
            return 0
        if self.residency.over_budget() == 0:
            return 0
        with self.obs.span("engine.drain.residency"):
            done = self.residency.enforce_budget(self.residency_budget)
        if done:
            self._m_residency_turns.inc()
        return done

    # ------------------------------------------------------------------
    def run_until_drained(self, max_steps: int = 10000) -> List[Request]:
        for _ in range(max_steps):
            if not self.queue and not self.ingest_queue \
                    and not self.query_queue \
                    and all(a is None for a in self.active):
                # cooperative maintenance keeps stepping until its backlog
                # (deferred flushes, compactions, merges) is drained too,
                # and residency until the hot set is back within budget
                if (self.maintenance is None or self.maintenance_budget <= 0
                        or self.maintenance.pending() == 0) \
                        and (self.residency is None
                             or self.residency_budget <= 0
                             or self.residency.over_budget() == 0):
                    break
            self.step()
        return self.finished

    def metrics(self) -> Dict[str, float]:
        """Legacy flat metrics dict, now REPORTED THROUGH the registry: every
        counter below is a ``serve/*`` registry counter (the properties read
        them back), so ``engine.obs.registry.snapshot()`` and this dict can
        never disagree (tests/test_obs.py metric-coherence test)."""
        steps = self._m_steps.value
        return {
            "decode_steps": steps,
            "decoded_tokens": self._m_decoded.value,
            "mean_occupancy": self._m_occupancy.value / max(steps, 1),
            "prefix_hits": self.prefix_cache.hits,
            "prefix_misses": self.prefix_cache.misses,
            "prefills": self._m_prefills.value,
            "prefills_reused": self._m_prefills_reused.value,
            "ingest_batches": self._m_ingest_batches.value,
            "ingest_sessions": self._m_ingest_sessions.value,
            "mean_ingest_batch": self._m_ingest_sessions.value
            / max(self._m_ingest_batches.value, 1),
            "query_batches": self._m_query_batches.value,
            "queries_served": self._m_queries_served.value,
            "mean_query_batch": self._m_queries_served.value
            / max(self._m_query_batches.value, 1),
            "maintenance_turns": self._m_maintenance_turns.value,
            "residency_turns": self._m_residency_turns.value,
            "serve_devices": (self.serve_mesh.devices.size
                              if self.serve_mesh is not None else 1),
            # per-request wait distributions (additive keys, seconds)
            "ingest_wait_p50_s": self._h_ingest_wait.quantile(0.5),
            "ingest_wait_p99_s": self._h_ingest_wait.quantile(0.99),
            "query_wait_p50_s": self._h_query_wait.quantile(0.5),
            "query_wait_p99_s": self._h_query_wait.quantile(0.99),
            **(self.maintenance.metrics() if self.maintenance is not None else {}),
            # hot_tenants / evictions / rehydrations / digest_answers /
            # device_bytes(_est) ride straight into the engine metrics dict
            **(self.residency.metrics() if self.residency is not None else {}),
        }

    def latency_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-phase span-duration summaries (populated while tracing is
        enabled): {span name: {count, mean_s, p50_s, p90_s, p99_s, ...}}."""
        return self.obs.registry.latency_summary()


class BatchedEncoderServer:
    """The extraction front-end: batches chunk-encode requests from many
    concurrent sessions into single forwards (the write-path parallelism),
    with shared-prefix accounting."""

    def __init__(self, encoder, shared_prefix: str = "[extract facts] "):
        self.encoder = encoder
        self.shared_prefix = shared_prefix
        self.prefix_tokens_saved = 0

    def encode_chunks(self, chunk_texts: List[str]) -> np.ndarray:
        # prefix is shared: tokens for it are paid once per batch, not per chunk
        n = len(chunk_texts)
        if n == 0:
            return np.zeros((0, self.encoder.dim), np.float32)
        prefix_tok = max(len(self.shared_prefix.split()), 1)
        self.prefix_tokens_saved += prefix_tok * (n - 1)
        return self.encoder.encode([self.shared_prefix + t for t in chunk_texts])
