"""Mixed-load serving benchmark (ISSUE 9): decode + ingest + query traffic
on one ServeEngine, per-phase p50/p99, three maintenance placements:

  * ``inline``       — no maintenance plane; ingest drains flush inline.
  * ``cooperative``  — MaintenancePlane drained in bounded slices between
                       decode steps (the engine's maintenance lane).
  * ``background``   — the same plane on its own worker thread
                       (``start_background``), engine budget 0.

For each mode the bench reports wall time, sessions/sec, queries/sec,
decoded tokens/sec, and the per-request latency distributions the engine
streams into its always-on registry histograms (``serve/ingest_wait_s``,
``serve/query_wait_s``, ``serve/decode_request_s``) — plus, from a second
tracing-enabled run of the same schedule, the per-phase span distributions
(``span/engine.step``, ``span/engine.drain.*``, ``span/forest.flush``, ...).
Answers are parity-checked across all three modes.

The overhead section asserts the observability tax stays ≤2% on the two
reference protocols (bench_ingest_batch's B=16 ingest, bench_query_latency's
B=32 query batch): the disabled-tracing cost is (no-op span cost x spans the
op would open), measured directly — the no-op call is microbenched and the
span count taken from a tracing-enabled run of the identical op. The
enabled-vs-disabled wall A/B is reported as well (informational; it is
noisier than the modeled bound).

CSV: mixed_<mode>,us_per_request,"sess_per_s=..;qps=..;tok_per_s=..;..."
``--json PATH`` writes the full document (BENCH_serving_mixed.json in CI);
``--small`` shrinks the workload for smoke runs.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

MODES = ("inline", "cooperative", "background")
OVERHEAD_MAX_PCT = 2.0
INGEST_B = 16           # bench_ingest_batch's reference batch
QUERY_B = 32            # bench_query_latency's reference batch
REPEATS = 3


# ---------------------------------------------------------------------------
# mixed engine schedule
# ---------------------------------------------------------------------------
def _build_engine(mode: str, model, params, mf):
    from repro.core.maintenance_plane import MaintenancePlane
    from repro.serving.engine import ServeEngine

    if mode == "inline":
        return ServeEngine(model, params, max_batch=4, max_len=64,
                           memory=mf), None
    plane = MaintenancePlane(mf.forest, flush_trees_per_unit=2)
    if mode == "cooperative":
        return ServeEngine(model, params, max_batch=4, max_len=64,
                           memory=mf, maintenance=plane,
                           maintenance_budget=2), plane
    eng = ServeEngine(model, params, max_batch=4, max_len=64,
                      memory=mf, maintenance=plane, maintenance_budget=0)
    return eng, plane


def _run_schedule(eng, sessions, queries, *, decode_every: int = 2,
                  queries_per_step: int = 4) -> List[str]:
    """Interleaved submission: one session, up to ``queries_per_step``
    queries, and (every ``decode_every`` steps) one short decode request per
    engine step — all three lanes stay busy together. Returns the query
    answers in submission order (parity-checked across modes)."""
    import numpy as np

    rng = np.random.default_rng(7)
    rids: List[int] = []
    si = qi = step = 0
    while si < len(sessions) or qi < len(queries):
        if si < len(sessions):
            eng.submit_session(sessions[si])
            si += 1
        for _ in range(queries_per_step):
            if qi < len(queries):
                rids.append(eng.submit_query(queries[qi]))
                qi += 1
        if step % decode_every == 0:
            eng.submit(list(rng.integers(3, 400, size=5)), max_new_tokens=3)
        eng.step()
        step += 1
    eng.run_until_drained()
    return [eng.pop_query_result(r).answer for r in rids]


def _hist_row(registry, name: str) -> Dict[str, float]:
    return registry.histogram(name).summary()


def _mode_row(mode: str, model, params, sessions, queries) -> Dict:
    """One benchmark row: a disabled-tracing run for throughput + the
    always-on wait histograms, then a tracing-enabled rerun of the same
    schedule for the per-phase span distributions."""
    from benchmarks.common import fresh_memforest
    from repro import obs as obs_mod

    def one_run():
        mf = fresh_memforest()
        eng, plane = _build_engine(mode, model, params, mf)
        if mode == "background":
            plane.start_background(interval_s=0.001, budget_per_wake=4)
        t0 = time.perf_counter()
        answers = _run_schedule(eng, sessions, queries)
        if plane is not None:
            plane.stop_background()
            plane.drain()
        return eng, answers, time.perf_counter() - t0

    eng, answers, _ = one_run()                       # warm jit caches
    eng, answers, wall = one_run()
    m = eng.metrics()
    reg = eng.obs.registry

    obs_mod.enable_tracing()
    eng_t, answers_t, wall_traced = one_run()
    obs_mod.disable_tracing()
    assert answers_t == answers, f"{mode}: tracing changed answers"
    phases = eng_t.latency_summary()

    n_req = len(sessions) + len(queries) + m["decode_steps"]
    return {
        "name": mode,
        "wall_s": wall,
        "wall_traced_s": wall_traced,
        "sessions": len(sessions), "queries": len(queries),
        "sess_per_s": len(sessions) / wall,
        "qps": len(queries) / wall,
        "tok_per_s": m["decoded_tokens"] / wall,
        "us_per_request": wall / max(n_req, 1) * 1e6,
        "mean_occupancy": m["mean_occupancy"],
        "maintenance_turns": m.get("maintenance_turns", 0),
        "ingest_wait": _hist_row(reg, "serve/ingest_wait_s"),
        "query_wait": _hist_row(reg, "serve/query_wait_s"),
        "decode_request": _hist_row(reg, "serve/decode_request_s"),
        "phases": phases,
        "answers": answers,
    }


# ---------------------------------------------------------------------------
# instrumentation overhead (the ≤2% guard)
# ---------------------------------------------------------------------------
def _noop_span_cost_s(iters: int = 200_000) -> float:
    """Per-call cost of a span site while tracing is disabled (one boolean
    check + the shared no-op context manager)."""
    from repro.obs import Observability

    o = Observability()
    t0 = time.perf_counter()
    for _ in range(iters):
        with o.span("bench.noop"):
            pass
    return (time.perf_counter() - t0) / iters


def _count_spans(fn) -> int:
    """Spans a single op opens, counted from a tracing-enabled run."""
    from repro import obs as obs_mod

    sink = obs_mod.MemorySink()
    obs_mod.enable_tracing(sink)
    try:
        fn()
    finally:
        obs_mod.disable_tracing()
    return sum(1 for r in sink.records if r.get("kind") == "span")


def _overhead_row(name: str, build_fn, op_fn, noop_s: float) -> Dict:
    """Overhead of the op's span sites while tracing is DISABLED:
    modeled = spans_per_op x no-op cost / disabled wall. The enabled wall is
    also measured for the (noisier) A/B delta."""
    from benchmarks.common import best_of
    from repro import obs as obs_mod

    state = build_fn()
    op_fn(state)                                       # warm
    wall = best_of(lambda: op_fn(build_fn()), REPEATS)

    spans = _count_spans(lambda: op_fn(build_fn()))

    obs_mod.enable_tracing()
    try:
        wall_enabled = best_of(lambda: op_fn(build_fn()), REPEATS)
    finally:
        obs_mod.disable_tracing()

    modeled_pct = spans * noop_s / wall * 100.0
    return {"name": name, "wall_s": wall, "wall_enabled_s": wall_enabled,
            "spans_per_op": spans,
            "overhead_disabled_pct": modeled_pct,
            "overhead_enabled_pct": (wall_enabled - wall) / wall * 100.0}


def _overhead_section(small: bool) -> Dict:
    from benchmarks.common import default_workload, emit, fresh_memforest

    noop_s = _noop_span_cost_s()
    wl = default_workload(num_entities=8, num_sessions=INGEST_B,
                          transitions_per_entity=3,
                          num_queries=QUERY_B, seed=5)
    ing_sessions = wl.sessions[:INGEST_B]

    def build_ingest():
        return fresh_memforest()

    def run_ingest(mf):
        mf.ingest_batch(ing_sessions)

    warm = fresh_memforest()
    warm.ingest_batch(ing_sessions)

    def build_query():
        return warm

    def run_query(mf):
        mf.query_batch(wl.queries[:QUERY_B])

    rows = [
        _overhead_row(f"ingest_B{INGEST_B}", build_ingest, run_ingest, noop_s),
        _overhead_row(f"query_B{QUERY_B}", build_query, run_query, noop_s),
    ]
    for r in rows:
        emit(f"overhead_{r['name']}", r["wall_s"] * 1e6,
             f"spans_per_op={r['spans_per_op']};"
             f"overhead_disabled_pct={r['overhead_disabled_pct']:.4f};"
             f"overhead_enabled_pct={r['overhead_enabled_pct']:.2f}")
        assert r["overhead_disabled_pct"] <= OVERHEAD_MAX_PCT, (
            f"{r['name']}: disabled-instrumentation overhead "
            f"{r['overhead_disabled_pct']:.3f}% > {OVERHEAD_MAX_PCT}% "
            f"({r['spans_per_op']} spans x {noop_s * 1e9:.0f}ns "
            f"on a {r['wall_s'] * 1e3:.1f}ms op)")
    return {"noop_span_cost_ns": noop_s * 1e9,
            "assert_max_pct": OVERHEAD_MAX_PCT, "rows": rows}


# ---------------------------------------------------------------------------
def run(small: bool = False, json_path: Optional[str] = None) -> None:
    import jax

    from benchmarks.common import default_workload, emit, write_json
    from repro.configs import get_smoke_config
    from repro.models import get_model

    if small:
        wl = default_workload(num_entities=4, num_sessions=8,
                              transitions_per_entity=3, num_queries=32,
                              seed=11)
    else:
        wl = default_workload(num_entities=8, num_sessions=14,
                              transitions_per_entity=4, num_queries=64,
                              seed=11)

    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))

    rows = []
    base_answers: Optional[List[str]] = None
    for mode in MODES:
        row = _mode_row(mode, model, params, wl.sessions, wl.queries)
        answers = row.pop("answers")
        if base_answers is None:
            base_answers = answers
        parity = sum(int(a == b) for a, b in
                     zip(answers, base_answers)) / max(len(answers), 1)
        row["parity_vs_inline"] = parity
        assert parity == 1.0, f"{mode}: answers diverged from inline mode"
        rows.append(row)
        emit(f"mixed_{mode}", row["us_per_request"],
             f"sess_per_s={row['sess_per_s']:.1f};qps={row['qps']:.1f};"
             f"tok_per_s={row['tok_per_s']:.0f};"
             f"ingest_wait_p99_ms={row['ingest_wait'].get('p99_s', 0) * 1e3:.2f};"
             f"query_wait_p99_ms={row['query_wait'].get('p99_s', 0) * 1e3:.2f};"
             f"parity={parity:.3f}")

    overhead = _overhead_section(small)

    if json_path:
        write_json(json_path, {
            "bench": "serving_mixed", "small": small,
            "ingest_batch": INGEST_B, "query_batch": QUERY_B,
            "modes": rows, "overhead": overhead})


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="smoke-scale workload (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full result document as JSON")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(small=args.small, json_path=args.json)
