"""Paper Tables 4/5 (system accuracy by category), Table 6 (tree-family
ablation), Table 7 (retrieval/browse ablation) on the synthetic temporal
workload with exact gold labels.

CSV rows:
  acc_<system>,0,"overall=..;current=..;historical=..;..."
  treefam_<combo>,0,"overall=.."
  browse_<mode>,0,"overall=.."
"""
from __future__ import annotations

from collections import defaultdict

from benchmarks.common import build_systems, default_workload, emit, fresh_memforest

BROWSE_MODES = ["flat", "root-only", "emb", "emb+planner", "llm", "llm+planner"]
TREE_FAMS = [
    ("entity", "scene", "session"),
    ("entity", "scene"),
    ("entity", "session"),
    ("scene", "session"),
    ("session",),
    ("scene",),
    ("entity",),
]


def _by_category(system, queries, mode=None):
    cats = defaultdict(lambda: [0, 0])
    for q in queries:
        r = system.query(q, mode=mode) if mode is not None else system.query(q)
        ok = r.answer.strip().lower() == q.gold.strip().lower()
        cats[q.qtype][0] += int(ok)
        cats[q.qtype][1] += 1
        cats["overall"][0] += int(ok)
        cats["overall"][1] += 1
    return {k: v[0] / v[1] for k, v in cats.items()}


def run() -> None:
    wl = default_workload()

    # --- Tables 4/5 analogue: systems by category --------------------------
    for name, mk in build_systems().items():
        sys_ = mk()
        for s in wl.sessions:
            sys_.ingest_session(s)
        cats = _by_category(sys_, wl.queries)
        emit(f"acc_{name}", 0.0,
             ";".join(f"{k}={v:.3f}" for k, v in sorted(cats.items())))

    # --- Table 6: tree-family ablation --------------------------------------
    for fams in TREE_FAMS:
        mf = fresh_memforest(tree_families=fams)
        for s in wl.sessions:
            mf.ingest_session(s)
        cats = _by_category(mf, wl.queries, mode="llm+planner")
        emit(f"treefam_{'+'.join(fams)}", 0.0, f"overall={cats['overall']:.3f}")

    # --- Table 7: browse-mode ablation ---------------------------------------
    mf = fresh_memforest()
    for s in wl.sessions:
        mf.ingest_session(s)
    for mode in BROWSE_MODES:
        cats = _by_category(mf, wl.queries, mode=mode)
        emit(f"browse_{mode}", 0.0, f"overall={cats['overall']:.3f}")


if __name__ == "__main__":
    run()
