"""Paper Figure 6: MemTree write-path scalability diagnostics.

  (a) lazy batch refresh vs eager per-insert refresh: #summary calls
  (b) tree build time vs number of facts
  (c) level-parallel flush speedup vs per-node flush, by tree size
  (d/e) branching-factor sweep: per-call summary capacity proxy + root recall

CSV rows: lazy_vs_eager_N<k>, build_time_N<k>, level_parallel_N<k>, ksweep_k<k>
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import EMB_DIM, emit
from repro.config import MemForestConfig
from repro.core.encoder import HashingEncoder
from repro.core.forest import Forest
from repro.kernels import ops
import jax.numpy as jnp


def _facts(rng, n):
    embs = rng.normal(size=(n, EMB_DIM)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True) + 1e-6
    return embs


def lazy_vs_eager(sizes=(64, 256, 1024)) -> None:
    rng = np.random.default_rng(0)
    for n in sizes:
        embs = _facts(rng, n)
        lazy = Forest(MemForestConfig(embed_dim=EMB_DIM))
        eager = Forest(MemForestConfig(embed_dim=EMB_DIM))
        for i in range(n):
            lazy.insert_item("entity:a", "entity", "fact", i, float(i), embs[i], f"f{i}")
        lazy.flush()
        for i in range(n):
            eager.insert_item("entity:a", "entity", "fact", i, float(i), embs[i], f"f{i}")
            eager.eager_refresh_path("entity:a")
        emit(f"lazy_vs_eager_N{n}", 0.0,
             f"lazy_calls={lazy.summary_refreshes};eager_calls={eager.summary_refreshes};"
             f"reduction={eager.summary_refreshes/max(lazy.summary_refreshes,1):.1f}x")


def build_time(sizes=(64, 256, 1024, 4096)) -> None:
    rng = np.random.default_rng(1)
    for n in sizes:
        embs = _facts(rng, n)
        f = Forest(MemForestConfig(embed_dim=EMB_DIM))
        t0 = time.perf_counter()
        for i in range(n):
            f.insert_item("entity:a", "entity", "fact", i, float(i), embs[i], f"f{i}")
        f.flush()
        dt = time.perf_counter() - t0
        emit(f"build_time_N{n}", dt * 1e6, f"per_fact_us={dt/n*1e6:.1f}")


def level_parallel(sizes=(64, 256, 1024)) -> None:
    rng = np.random.default_rng(2)
    for n in sizes:
        embs = _facts(rng, n)

        def mk():
            f = Forest(MemForestConfig(embed_dim=EMB_DIM))
            for i in range(n):
                f.insert_item("entity:a", "entity", "fact", i, float(i), embs[i], f"f{i}")
            return f

        fa, fb = mk(), mk()
        t0 = time.perf_counter(); ra = fa.flush(level_parallel=True); t_par = time.perf_counter() - t0
        t0 = time.perf_counter(); rb = fb.flush(level_parallel=False); t_seq = time.perf_counter() - t0
        emit(f"level_parallel_N{n}", t_par * 1e6,
             f"kernel_calls_par={ra['kernel_calls']};kernel_calls_seq={rb['kernel_calls']};"
             f"speedup={t_seq/max(t_par,1e-9):.2f}x")


def k_sweep(ks=(3, 4, 8, 16, 32, 64), n: int = 512) -> None:
    """(d) summary-capacity proxy: cosine between a parent summary and its
    children's true mean degrades as k grows past the knee (more children ->
    flatter, lossier text summaries; embedding mean stays exact, so the
    capacity proxy is the ROOT-RECALL hit rate below).
    (e) end-to-end root recall: query with a leaf's embedding; is the owning
    tree's root ranked first among all roots?"""
    rng = np.random.default_rng(3)
    n_trees = 16
    for k in ks:
        cfg = MemForestConfig(embed_dim=EMB_DIM, branching_factor=k)
        f = Forest(cfg)
        owner = {}
        fact_embs = np.zeros((n, EMB_DIM), np.float32)
        for t in range(n_trees):
            base = rng.normal(size=EMB_DIM).astype(np.float32)
            base /= np.linalg.norm(base)
            for i in range(n // n_trees):
                e = base + 0.9 * rng.normal(size=EMB_DIM).astype(np.float32)
                e /= np.linalg.norm(e) + 1e-6
                fid = t * (n // n_trees) + i
                fact_embs[fid] = e
                f.insert_item(f"entity:e{t}", "entity", "fact", fid, float(i), e, f"f{fid}")
                owner[fid] = t
        f.flush()
        roots, n_valid, order = f.root_index()
        hits = 0
        trials = 128
        for _ in range(trials):
            fid = int(rng.integers(0, n))
            q = fact_embs[fid]
            vals, idx = ops.topk_sim(jnp.asarray(q[None]), jnp.asarray(roots), 1,
                                     num_valid=n_valid)
            hit_tree = order[int(np.asarray(idx)[0, 0])]
            hits += int(hit_tree == f"entity:e{owner[fid]}")
        height = max(t.height for t in f.trees.values())
        emit(f"ksweep_k{k}", 0.0,
             f"root_recall={hits/trials:.3f};height={height}")


def run() -> None:
    lazy_vs_eager()
    build_time()
    level_parallel()
    k_sweep()


if __name__ == "__main__":
    run()
