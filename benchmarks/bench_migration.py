"""Paper Figure 5 + Table 10: migration merge vs sequential write on
progressively combined memory instances.

Sequential write replays every raw session through extraction — in a real
deployment that is LLM work (the latency model of bench_write_path:
T_CALL per sequential round + tokens/TOK_RATE). Migration merge reuses the
already-materialized state: its only LLM work is the dirty-path summary
refresh after the merge. Both modeled and CPU-measured times are reported;
state-scale parity (Table 10) is checked from the same run.

CSV: migration_N<k>,measured_mig_us,
     "speedup_modeled=..;speedup_measured=..;facts_seq=..;facts_mig=..;trees_seq=..;trees_mig=.."
"""
from __future__ import annotations

import time

from benchmarks.common import default_workload, fresh_memforest, emit
from benchmarks.bench_write_path import T_CALL, TOK_RATE

TOK_PER_SUMMARY = 100  # refresh call ~= one short summary generation


def _build(sessions):
    mf = fresh_memforest()
    depth_sum = 0
    for s in sessions:
        st = mf.ingest_session(s)
        depth_sum += st.llm_dependency_depth
    return mf, depth_sum


def run(max_n: int = 8) -> None:
    # N independent "instances" (separate users): distinct seeds
    instances = [default_workload(seed=100 + i, num_sessions=4, num_entities=3,
                                  num_queries=1).sessions for i in range(max_n)]
    prebuilt = [_build(ss)[0] for ss in instances]

    for n in range(2, max_n + 1):
        # sequential write: replay ALL sessions through the write path
        t0 = time.perf_counter()
        seq, seq_depth = _build([s for ss in instances[:n] for s in ss])
        t_seq = time.perf_counter() - t0
        seq_modeled = seq_depth * T_CALL + seq.write_stats.encoder_tokens / TOK_RATE

        # migration merge: combine already-materialized states
        t0 = time.perf_counter()
        mig, _ = _build(instances[0])
        mig_llm_rounds = 0
        refreshes0 = mig.forest.summary_refreshes
        for other in prebuilt[1:n]:
            flush_before = mig.forest.flush_levels
            mig.merge_from(other)
            mig_llm_rounds += mig.forest.flush_levels - flush_before
        t_mig = time.perf_counter() - t0
        mig_refreshes = mig.forest.summary_refreshes - refreshes0
        mig_modeled = (
            4 * T_CALL  # instance-0 build rounds (bounded by tree height)
            + mig_llm_rounds * T_CALL
            + mig_refreshes * TOK_PER_SUMMARY / TOK_RATE
        )

        s_seq, s_mig = seq.scale_stats(), mig.scale_stats()
        emit(
            f"migration_N{n}", t_mig * 1e6,
            f"speedup_modeled={seq_modeled/max(mig_modeled,1e-9):.2f}x;"
            f"speedup_measured={t_seq/max(t_mig,1e-9):.2f}x;"
            f"facts_seq={s_seq['facts']};facts_mig={s_mig['facts']};"
            f"trees_seq={s_seq['trees']};trees_mig={s_mig['trees']}",
        )


if __name__ == "__main__":
    run()
