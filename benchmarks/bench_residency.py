"""Tiered tenant residency under Zipfian traffic (core/residency.py).

Sweeps tenant counts at {1, 8, 32}x the hot budget. For each factor the same
Zipf-distributed query schedule runs twice against two managers over
identical per-tenant corpora:

  * ``tiered``  — hot_budget tenants resident, traffic-aware LRU eviction,
    cold queries through the digest gate (escalate only above threshold);
  * ``all_hot`` — budget = tenant count, so every tenant stays resident
    (the no-eviction upper bound at equal hot-set size).

Steady state: one full pass of the schedule warms both managers (LRU
stabilizes on the Zipf head, jit shapes compile), then the timed pass
reports qps and ``qps_vs_all_hot``. Residency counters (evictions /
rehydrations / digest_answers / device bytes) are deltas over the timed
pass and ride in BOTH emitters — the CSV ``derived`` column and the JSON
rows (BENCH_residency.json in CI).

A parity row runs every query against one tenant before demotion and after
rehydration (escalation forced) — byte-identical answers required
(parity=1.0, asserted): eviction must never cost fidelity.

CSV: residency_<f>x,us_per_query,"qps=..;qps_vs_all_hot=..;evictions=..;.."
     residency_parity,us_per_query,"parity=1.000;..."
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import List, Optional

FACTORS = (1, 8, 32)
HOT_BUDGET = 4
ZIPF_S = 1.5                 # traffic skew: head tenants dominate
DIGEST_THRESHOLD = 0.45      # cold tail mostly answers from the digest
EVENT_BATCH = 4              # queries per traffic event (one drain's worth)


def _tenant_wl(i: int, small: bool):
    from repro.data.synthetic import make_workload

    return make_workload(num_entities=2, num_sessions=2 if small else 3,
                         transitions_per_entity=2 if small else 3,
                         num_queries=6, seed=1000 + i)


def _schedule(n_tenants: int, n_events: int, nq: int):
    """Zipf-ranked tenant draw + rotating query pick, fixed seed — the
    identical schedule drives the tiered and the all-hot manager."""
    import numpy as np

    rng = np.random.default_rng(7)
    p = 1.0 / np.arange(1, n_tenants + 1, dtype=np.float64) ** ZIPF_S
    p /= p.sum()
    ranks = rng.choice(n_tenants, size=n_events, p=p)
    return [(int(r), [(e * EVENT_BATCH + j) % nq for j in range(EVENT_BATCH)])
            for e, r in enumerate(ranks)]


def _build_manager(root: str, budget: int, threshold: float, wls) -> "object":
    from repro.config import MemForestConfig
    from repro.core.residency import ResidencyConfig, ResidencyManager

    mgr = ResidencyManager(root, config=ResidencyConfig(
        hot_budget=budget, digest_threshold=threshold),
        mem_config=MemForestConfig())
    for i, wl in enumerate(wls):
        mgr.ingest(f"t{i:03d}", wl.sessions, idempotency_key=f"t{i:03d}:i0")
    return mgr


def _run_schedule(mgr, wls, sched) -> float:
    t0 = time.perf_counter()
    for rank, q_idx in sched:
        qs = [wls[rank].queries[j] for j in q_idx]
        mgr.query_batch(f"t{rank:03d}", qs)
    return time.perf_counter() - t0


def _factor_row(factor: int, small: bool, base: str) -> dict:
    from benchmarks.common import emit

    n_tenants = factor * HOT_BUDGET
    n_events = 40 if small else 120
    wls = [_tenant_wl(i, small) for i in range(n_tenants)]
    sched = _schedule(n_tenants, n_events, len(wls[0].queries))
    n_queries = n_events * EVENT_BATCH

    tiered = _build_manager(os.path.join(base, f"tiered_{factor}x"),
                            HOT_BUDGET, DIGEST_THRESHOLD, wls)
    all_hot = _build_manager(os.path.join(base, f"allhot_{factor}x"),
                             n_tenants, DIGEST_THRESHOLD, wls)

    _run_schedule(tiered, wls, sched)       # warm: LRU settles on the head
    _run_schedule(all_hot, wls, sched)
    m0 = tiered.metrics()
    wall = _run_schedule(tiered, wls, sched)
    wall_hot = _run_schedule(all_hot, wls, sched)
    m1 = tiered.metrics()

    qps = n_queries / wall
    qps_hot = n_queries / wall_hot
    ratio = qps / qps_hot
    delta = {k: m1[k] - m0[k] for k in
             ("evictions", "rehydrations", "digest_answers",
              "digest_escalations")}
    row = {
        "name": f"residency_{factor}x",
        "tenants": n_tenants, "hot_budget": HOT_BUDGET,
        "qps": qps, "qps_all_hot": qps_hot, "qps_vs_all_hot": ratio,
        "us_per_query": wall / n_queries * 1e6,
        "hot_tenants": m1["hot_tenants"],
        "device_bytes": m1["device_bytes"],
        "device_bytes_est": m1["device_bytes_est"],
        "device_bytes_all_hot": all_hot.metrics()["device_bytes_est"],
        "digest_bytes": m1["digest_bytes"],
        **delta,
    }
    emit(f"residency_{factor}x", row["us_per_query"],
         f"qps={qps:.1f};qps_vs_all_hot={ratio:.3f};"
         f"hot_tenants={row['hot_tenants']};evictions={delta['evictions']};"
         f"rehydrations={delta['rehydrations']};"
         f"digest_answers={delta['digest_answers']};"
         f"device_bytes_est={row['device_bytes_est']}")
    tiered.close()
    all_hot.close()
    return row


def _parity_row(small: bool, base: str) -> dict:
    """Evict -> rehydrate fidelity: identical answers required. Escalation
    is forced (threshold < 0) so the post-demotion pass runs on the
    rehydrated store, not the digest."""
    from benchmarks.common import emit

    wl = _tenant_wl(0, small)
    mgr = _build_manager(os.path.join(base, "parity"), 2, -99.0, [wl])
    before = [r.answer for r in mgr.query_batch("t000", wl.queries)]
    assert mgr.demote("t000")
    t0 = time.perf_counter()
    after = [r.answer for r in mgr.query_batch("t000", wl.queries)]
    wall = time.perf_counter() - t0
    parity = sum(int(a == b) for a, b in zip(after, before)) / len(before)
    m = mgr.metrics()
    emit("residency_parity", wall / len(wl.queries) * 1e6,
         f"parity={parity:.3f};rehydrations={m['rehydrations']};"
         f"evictions={m['evictions']}")
    assert parity == 1.0, "rehydrated answers diverged from pre-eviction"
    mgr.close()
    return {"name": "residency_parity", "parity": parity,
            "us_per_query": wall / len(wl.queries) * 1e6,
            "rehydrations": m["rehydrations"], "evictions": m["evictions"]}


def run(small: bool = False, json_path: Optional[str] = None) -> None:
    base = tempfile.mkdtemp(prefix="memforest_resid_")
    try:
        rows: List[dict] = [_parity_row(small, base)]
        for f in FACTORS:
            rows.append(_factor_row(f, small, base))
        if json_path:
            doc = {"bench": "residency", "small": small,
                   "hot_budget": HOT_BUDGET, "zipf_s": ZIPF_S,
                   "digest_threshold": DIGEST_THRESHOLD,
                   "event_batch": EVENT_BATCH, "rows": rows}
            with open(json_path, "w") as fh:
                json.dump(doc, fh, indent=2)
            print(f"# wrote {json_path}", flush=True)
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="smoke-scale workload (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the sweep rows as JSON")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(small=args.small, json_path=args.json)
