"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>]

Prints ``name,us_per_call,derived`` CSV rows. Mapping to the paper:
  bench_write_path     Table 1 + Table 2   (write latency / tokens / depth)
  bench_query_latency  Table 3             (retrieval vs answer split)
  bench_accuracy       Tables 4,5,6,7      (accuracy + ablations)
  bench_migration      Figure 5 + Table 10 (migration merge)
  bench_tree_scaling   Figure 6a-e         (lazy refresh, build, parallel, k)
  bench_chunk_sweep    Table 8             (extraction operating point)
  bench_kernels        (kernel layer)      (per-kernel µs + ref deltas)
  bench_ingest_batch   (beyond paper)      (cross-tenant batched write path)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_accuracy,
    bench_chunk_sweep,
    bench_ingest_batch,
    bench_kernels,
    bench_migration,
    bench_query_latency,
    bench_tree_scaling,
    bench_write_path,
)

SUITES = {
    "write_path": bench_write_path.run,
    "ingest_batch": bench_ingest_batch.run,
    "query_latency": bench_query_latency.run,
    "accuracy": bench_accuracy.run,
    "migration": bench_migration.run,
    "tree_scaling": bench_tree_scaling.run,
    "chunk_sweep": bench_chunk_sweep.run,
    "kernels": bench_kernels.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.time()
        try:
            SUITES[name]()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
        print(f"# suite {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
