"""Paper Table 2 + Table 1: write-path cost for MemForest vs the five
baseline classes.

Two numbers per system:
  * measured_us — CPU wall-clock of the full write path with the hashing
    encoder (measures the SYSTEM: batching, maintenance, index updates).
  * modeled_s   — wall-clock under the builder-LLM latency model
        modeled = Σ_sessions depth_s × T_CALL + total_tokens / TOK_RATE
    with T_CALL = 0.2 s (per sequential LLM round: queueing + prefill floor)
    and TOK_RATE = 5000 tok/s (batched token processing). depth_s is the
    MEASURED per-session dependency depth. This is the Table-2 analogue: on
    real serving hardware the sequential-round count dominates, which is
    exactly the paper's argument (§2.3, Appendix B).

CSV: writepath_<system>,measured_us_per_session,
     "modeled_s=..;speedup=..;tokens=..;calls=..;depth_avg=.."
(speedup = modeled time of the slowest stateful system / this system's.)
"""
from __future__ import annotations

import time

from benchmarks.common import build_systems, default_workload, emit

T_CALL = 0.2
TOK_RATE = 5000.0


def run() -> None:
    wl = default_workload()
    rows = {}
    for name, mk in build_systems().items():
        sys_ = mk()
        sys_.ingest_session(wl.sessions[0])  # jit warmup
        depth_sum = 0
        t0 = time.perf_counter()
        for s in wl.sessions[1:]:
            st = sys_.ingest_session(s)
            depth_sum += st.llm_dependency_depth
        wall = time.perf_counter() - t0
        n = len(wl.sessions) - 1
        stats = sys_.write_stats
        modeled = depth_sum * T_CALL + stats.encoder_tokens / TOK_RATE
        rows[name] = dict(
            wall=wall / n, modeled=modeled, tokens=stats.encoder_tokens,
            calls=stats.encoder_calls, depth_avg=depth_sum / n,
        )
    slowest = max(r["modeled"] for r in rows.values())
    for name, r in rows.items():
        emit(
            f"writepath_{name}",
            r["wall"] * 1e6,
            f"modeled_s={r['modeled']:.1f};speedup={slowest / r['modeled']:.1f}x;"
            f"tokens={r['tokens']};calls={r['calls']};depth_avg={r['depth_avg']:.1f}",
        )


if __name__ == "__main__":
    run()
