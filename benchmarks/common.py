"""Shared benchmark utilities: builders, timing, CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

from repro.config import MemForestConfig
from repro.core.baselines import ALL_BASELINES
from repro.core.encoder import HashingEncoder
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import Workload, make_workload

EMB_DIM = 256


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def fresh_memforest(**cfg_kw) -> MemForestSystem:
    cfg = MemForestConfig(**cfg_kw)
    return MemForestSystem(cfg, HashingEncoder(dim=cfg.embed_dim))


def fresh_baseline(name: str):
    return ALL_BASELINES[name](HashingEncoder(dim=EMB_DIM))


def build_systems() -> Dict[str, Callable[[], object]]:
    out: Dict[str, Callable[[], object]] = {"memforest": fresh_memforest}
    for name in ALL_BASELINES:
        out[name] = (lambda n=name: fresh_baseline(n))
    return out


def default_workload(seed: int = 1, **kw) -> Workload:
    base = dict(num_entities=8, num_sessions=14, transitions_per_entity=4,
                num_queries=60, seed=seed)
    base.update(kw)
    return make_workload(**base)


def accuracy(system, queries, *, mode=None, final_topk: int = 6) -> float:
    correct = 0
    for q in queries:
        if mode is not None:
            r = system.query(q, mode=mode, final_topk=final_topk)
        else:
            r = system.query(q, final_topk=final_topk)
        correct += int(r.answer.strip().lower() == q.gold.strip().lower())
    return correct / max(len(queries), 1)


def time_fn(fn: Callable, *, repeats: int = 3) -> float:
    """Median wall seconds."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]
