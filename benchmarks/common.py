"""Shared benchmark utilities: builders, timing, percentiles, emission."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Sequence

from repro.config import MemForestConfig
from repro.core.baselines import ALL_BASELINES
from repro.core.encoder import HashingEncoder
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import Workload, make_workload
from repro.obs.metrics import percentiles  # noqa: F401 (re-export)

EMB_DIM = 256


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def fresh_memforest(**cfg_kw) -> MemForestSystem:
    cfg = MemForestConfig(**cfg_kw)
    return MemForestSystem(cfg, HashingEncoder(dim=cfg.embed_dim))


def fresh_baseline(name: str):
    return ALL_BASELINES[name](HashingEncoder(dim=EMB_DIM))


def build_systems() -> Dict[str, Callable[[], object]]:
    out: Dict[str, Callable[[], object]] = {"memforest": fresh_memforest}
    for name in ALL_BASELINES:
        out[name] = (lambda n=name: fresh_baseline(n))
    return out


def default_workload(seed: int = 1, **kw) -> Workload:
    base = dict(num_entities=8, num_sessions=14, transitions_per_entity=4,
                num_queries=60, seed=seed)
    base.update(kw)
    return make_workload(**base)


def accuracy(system, queries, *, mode=None, final_topk: int = 6) -> float:
    correct = 0
    for q in queries:
        if mode is not None:
            r = system.query(q, mode=mode, final_topk=final_topk)
        else:
            r = system.query(q, final_topk=final_topk)
        correct += int(r.answer.strip().lower() == q.gold.strip().lower())
    return correct / max(len(queries), 1)


def time_fn(fn: Callable, *, repeats: int = 3) -> float:
    """Median wall seconds."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def best_of(fn: Callable, repeats: int = 3) -> float:
    """Best (min) wall seconds over ``repeats`` runs — the standard
    measurement for the throughput benches (first run warms jit caches)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def latency_row(samples: Sequence[float],
                qs: Sequence[float] = (0.50, 0.90, 0.99)) -> Dict[str, float]:
    """{count, mean_s, p50_s, p90_s, p99_s, max_s} from raw wall samples
    (exact sort — the benches' reference; the serve registry's streaming
    histograms approximate the same stats within their bucket error)."""
    if not samples:
        return {"count": 0}
    ps = percentiles(samples, qs)           # {"p50": v, "p90": v, ...}
    return {"count": len(samples),
            "mean_s": sum(samples) / len(samples),
            **{f"{k}_s": v for k, v in ps.items()},
            "max_s": max(samples)}


def write_json(path: str, doc: Dict) -> None:
    """Write a bench JSON document (the CI artifact format) + a marker."""
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {path}", flush=True)
