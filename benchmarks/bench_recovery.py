"""Durability tax + recovery speed for the journaled write path
(core/journal.py).

Three questions the numbers answer:

  * **journal-append overhead**: ``DurableMemForest.ingest_batch`` vs the
    plain ``MemForestSystem.ingest_batch`` at B=16. The write path's
    durability contract budgets <= 5% on the group-commit configuration
    (``fsync=False`` — a crash can lose the un-acked tail but never break
    exactly-once, because clients retry under the same idempotency key);
    the ``fsync=True`` per-op-ack row is reported for the webhook-ack
    operating point.
  * **replay-only recovery**: ``DurableMemForest.open`` against a journal
    with NO snapshot — the worst case, every op re-executes.
  * **snapshot+tail recovery**: open after a checkpoint — restore is a
    snapshot load plus an empty (or short) tail, independent of history
    length.

CSV: ingest_plain_B16,us_per_session
     ingest_journaled_B16,us_per_session,"overhead_pct=..;target_pct=5.0"
     ingest_journaled_fsync_B16,us_per_session,"overhead_pct=.."
     recover_replay_only,us_total,"ops_replayed=.."
     recover_snapshot_tail,us_total,"ops_replayed=..;speedup_vs_replay=.."

``--json PATH`` writes the same rows as a JSON document (BENCH_recovery.json
in CI) so the durability-tax trajectory is tracked across PRs; ``--small``
shrinks the workload for smoke runs.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Callable, List, Optional

from benchmarks.common import default_workload, emit, fresh_memforest
from repro.core.journal import DurableMemForest, JournalWriter, _session_rec

B = 16
REPEATS = 3
TARGET_OVERHEAD_PCT = 5.0


def _median(ts: List[float]) -> float:
    ts = sorted(ts)
    return ts[len(ts) // 2]


INGEST_ROUNDS = 6      # batches per sample — amortizes ms-scale wall jitter


def _plain_ingest_once(sessions) -> float:
    mf = fresh_memforest()
    t0 = time.perf_counter()
    for _ in range(INGEST_ROUNDS):
        mf.ingest_batch(sessions)
    return time.perf_counter() - t0


def _journaled_ingest_once(sessions, root: str, *, fsync: bool) -> float:
    store = DurableMemForest(fresh_memforest(), root, fsync=fsync)
    t0 = time.perf_counter()
    for r in range(INGEST_ROUNDS):
        store.ingest_batch(sessions, idempotency_key=f"bench:ingest:{r}")
    dt = time.perf_counter() - t0
    store.close()
    return dt


def _measure_ingest_tax(sessions, base: str, repeats: int = REPEATS):
    """Round-robin sampling (plain, group-commit, fsync) per repeat so
    allocator/cache warmth drift hits every configuration equally; best-of
    per configuration (same estimator as bench_query_latency) since the
    floor, not the noise tail, is the durability tax we are measuring.
    Re-ingesting the same batch each round is identical forest work on both
    paths, so the delta isolates the journal append."""
    samples = {"plain": [], "nofsync": [], "fsync": []}
    for r in range(repeats):
        samples["plain"].append(_plain_ingest_once(sessions))
        samples["nofsync"].append(_journaled_ingest_once(
            sessions, os.path.join(base, f"ing_nf_{r}"), fsync=False))
        samples["fsync"].append(_journaled_ingest_once(
            sessions, os.path.join(base, f"ing_fs_{r}"), fsync=True))
    return {k: min(v) / INGEST_ROUNDS for k, v in samples.items()}


def _journal_append_cost(sessions, base: str, *, n: int = 200) -> float:
    """Seconds per append of a full B-session ingest record (serialization
    included) in group-commit mode — the exact work the durable path adds
    to each ingest_batch. Direct measurement: stable where the end-to-end
    A/B is at the mercy of multi-ms wall jitter."""
    w = JournalWriter(os.path.join(base, "direct.waj"), fsync=False)
    payload_of = lambda: {"sessions": [_session_rec(s) for s in sessions]}
    w.append({"seq": 0, "op": "ingest_batch", "key": "warm",
              "payload": payload_of()})
    t0 = time.perf_counter()
    for i in range(n):
        w.append({"seq": i + 1, "op": "ingest_batch", "key": f"k{i}",
                  "payload": payload_of()})
    dt = (time.perf_counter() - t0) / n
    w.close()
    return dt


def _seed_store(root: str, sessions, *, batch: int = 4) -> int:
    """Journal a realistic op history: batched ingests + one deletion.
    Returns the op count."""
    store = DurableMemForest(fresh_memforest(), root, fsync=False)
    ops = 0
    for i in range(0, len(sessions), batch):
        store.ingest_batch(sessions[i:i + batch],
                           idempotency_key=f"bench:i{i}")
        ops += 1
    store.delete_session(sessions[0].session_id, idempotency_key="bench:d0")
    store.close()
    return ops + 1


def _time_open(root: str) -> float:
    ts = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        store = DurableMemForest.open(root, fsync=False)
        ts.append(time.perf_counter() - t0)
        store.close()
    return _median(ts)


def run(small: bool = False, json_path: Optional[str] = None) -> None:
    if small:
        wl = default_workload(num_entities=4, num_sessions=B,
                              transitions_per_entity=2, num_queries=4)
    else:
        wl = default_workload(num_entities=8, num_sessions=2 * B,
                              transitions_per_entity=4, num_queries=4)
    batch = wl.sessions[:B]
    rows: List[dict] = []
    base = tempfile.mkdtemp(prefix="memforest_bench_recovery_")
    try:
        # --- durability tax on the ingest hot path -----------------------
        fresh_memforest().ingest_batch(batch)     # warm jit shape buckets
        meds = _measure_ingest_tax(batch, base,
                                   repeats=REPEATS if small else 2 * REPEATS)
        plain = meds["plain"]
        emit(f"ingest_plain_B{B}", plain / B * 1e6)
        rows.append({"name": f"ingest_plain_B{B}",
                     "us_per_session": plain / B * 1e6})
        for key, fsync, label in (("nofsync", False, f"ingest_journaled_B{B}"),
                                  ("fsync", True,
                                   f"ingest_journaled_fsync_B{B}")):
            wall = meds[key]
            overhead = (wall - plain) / plain * 100.0
            emit(label, wall / B * 1e6, f"overhead_pct={overhead:.2f}")
            rows.append({"name": label, "us_per_session": wall / B * 1e6,
                         "overhead_pct": overhead, "fsync": fsync})

        # the contract number: directly-measured append cost per B-session
        # record, as a fraction of the plain ingest wall
        append_s = _journal_append_cost(batch, base)
        direct_pct = append_s / plain * 100.0
        emit(f"journal_append_B{B}", append_s * 1e6,
             f"overhead_pct={direct_pct:.2f};"
             f"target_pct={TARGET_OVERHEAD_PCT:.1f}")
        rows.append({"name": f"journal_append_B{B}",
                     "us_per_append": append_s * 1e6,
                     "overhead_pct": direct_pct,
                     "target_pct": TARGET_OVERHEAD_PCT})

        # --- recovery: pure replay vs snapshot + tail --------------------
        replay_root = os.path.join(base, "replay_only")
        ops = _seed_store(replay_root, wl.sessions)
        t_replay = _time_open(replay_root)
        emit("recover_replay_only", t_replay * 1e6, f"ops_replayed={ops}")
        rows.append({"name": "recover_replay_only", "us_total": t_replay * 1e6,
                     "ops_replayed": ops})

        snap_root = os.path.join(base, "snapshot_tail")
        _seed_store(snap_root, wl.sessions)
        store = DurableMemForest.open(snap_root, fsync=False)
        store.checkpoint()
        store.close()
        t_snap = _time_open(snap_root)
        emit("recover_snapshot_tail", t_snap * 1e6,
             f"ops_replayed=0;speedup_vs_replay={t_replay / t_snap:.2f}x")
        rows.append({"name": "recover_snapshot_tail",
                     "us_total": t_snap * 1e6, "ops_replayed": 0,
                     "speedup_vs_replay": t_replay / t_snap})
    finally:
        shutil.rmtree(base, ignore_errors=True)

    if json_path:
        doc = {"bench": "recovery", "B": B, "small": small,
               "target_overhead_pct": TARGET_OVERHEAD_PCT, "rows": rows}
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_path}", flush=True)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="smoke-scale workload (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as JSON")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(small=args.small, json_path=args.json)
