"""Paper Table 8 / Appendix C: chunk-size operating point for raw-fact
extraction on assembled long sessions.

Ent-GR (entity gold-range retention): fraction of gold answer spans still
present in SOME extracted fact. Larger chunks exceed the extraction call's
output budget and drop statements; tiny chunks maximize retention but cost
more calls/tokens per fact.

CSV: chunk_<b>turn,us_per_session,"entgr=..;facts_per_s=..;tok_per_fact=.."
"""
from __future__ import annotations

import time

from benchmarks.common import default_workload, emit
from repro.config import MemForestConfig
from repro.core.encoder import HashingEncoder
from repro.core.extraction import ParallelExtractor
from repro.core.types import Session


def _assemble_long_sessions(wl, group: int = 4):
    """Concatenate original conversations into long sessions (the paper's
    controlled stress setting)."""
    out = []
    ss = wl.sessions
    for i in range(0, len(ss) - group + 1, group):
        turns = []
        for s in ss[i:i + group]:
            turns.extend(s.turns)
        out.append(Session(f"long{i}", turns))
    return out


CONCURRENCY = 64      # parallel extraction budget (paper: "up to the
                      # concurrency budget")
T_CALL = 0.2          # per-call latency floor
TOK_RATE_CALL = 2000  # single-call token throughput
PROMPT_TOKENS = 30    # extraction-instruction prefix paid per call


def run() -> None:
    # dense statement stream so the extraction output budget binds at large
    # chunk sizes (the paper's assembled-long-session stress setting)
    wl = default_workload(num_sessions=16, num_queries=40, num_entities=10,
                          transitions_per_entity=6, distractor_turns=2)
    longs = _assemble_long_sessions(wl)
    golds = [(q.subject, q.gold) for q in wl.queries]

    for b in (1, 2, 4, 8, 16, 32):
        enc = HashingEncoder(dim=256)
        ex = ParallelExtractor(enc, chunk_turns=b)
        t0 = time.perf_counter()
        all_facts = []
        n_chunks = 0
        modeled_wall = 0.0
        for s in longs:
            tok0 = enc.stats.tokens
            cands, _embs, _cells, _st = ex.extract_session(s)
            all_facts.extend(cands)
            nc = -(-len(s.turns) // b)
            n_chunks += nc
            tok_per_chunk = (enc.stats.tokens - tok0) / max(nc, 1)
            rounds = -(-nc // CONCURRENCY)
            # chunks of one session run in parallel up to the budget
            modeled_wall += rounds * (
                T_CALL + (PROMPT_TOKENS + tok_per_chunk) / TOK_RATE_CALL
            )
        wall = time.perf_counter() - t0
        texts = " || ".join(c.text.lower() for c in all_facts)
        retained = sum(
            1 for subj, gold in golds
            if gold.lower() in texts and subj.lower() in texts
        )
        entgr = retained / max(len(golds), 1)
        fps_model = len(all_facts) / max(modeled_wall, 1e-9)
        tpf = (enc.stats.tokens + PROMPT_TOKENS * n_chunks) / max(len(all_facts), 1)
        emit(f"chunk_{b}turn", wall / len(longs) * 1e6,
             f"entgr={entgr:.3f};facts_per_s_modeled={fps_model:.2f};"
             f"tok_per_fact={tpf:.0f}")


if __name__ == "__main__":
    run()
