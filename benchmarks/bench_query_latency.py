"""Paper Table 3: query-time latency breakdown (retrieval vs answer) for the
two MemForest operating points and the baselines — plus the batched read
path sweep (beyond paper): queries/sec for ``query_batch`` at
B in {1, 8, 32, 64} against the per-query ``query()`` loop, with an answer
parity check (the batched path must be result-identical).

CSV: query_<system>,us_per_query,"retrieval_us=..;answer_us=..;acc=.."
     query_batch_B<k>,us_per_query,"qps=..;speedup_vs_per_query=..;parity=..;acc=.."

``--json PATH`` additionally writes the sweep rows as a JSON document
(BENCH_query.json in CI) so the perf trajectory is tracked across PRs;
``--small`` shrinks the workload for smoke runs.

``--devices N`` switches to the multi-device serve sweep instead: forces
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (BEFORE any jax
import — which is why every jax-touching import in this module lives inside
``run``), then reports ingest sessions/sec and query-batch qps per mesh size
in {1, 2, 4} (capped at N), with an exact answer-parity check against the
single-device rows (BENCH_shard.json in CI). Host-simulated devices share
one CPU, so this measures sharding overhead/parity, not real scaling.
"""
from __future__ import annotations

import time
from typing import List, Optional

SWEEP_BATCHES = (1, 8, 32, 64)
SWEEP_MODE = "llm+planner"          # the paper's default operating point
DEVICE_SWEEP = (1, 2, 4)
REPEATS = 3


def _best_of(fn, repeats: int = REPEATS) -> float:
    from benchmarks.common import best_of
    return best_of(fn, repeats)


def _accuracy(answers, queries) -> float:
    return sum(int(a.strip().lower() == q.gold.strip().lower())
               for a, q in zip(answers, queries)) / max(len(queries), 1)


def _batch_sweep(mf, queries, json_rows: Optional[list]) -> None:
    """Per-query retrieve() loop vs query_batch at each B — identical
    answers required (parity), throughput reported as queries/sec."""
    from benchmarks.common import emit, latency_row

    n = len(queries)
    # warm every jit shape bucket both paths touch
    mf.query(queries[0], mode=SWEEP_MODE)
    for b in SWEEP_BATCHES:
        mf.query_batch(queries[:b], mode=SWEEP_MODE)

    base_answers = [mf.query(q, mode=SWEEP_MODE).answer for q in queries]
    base_samples: List[float] = []

    def per_query_pass():
        for q in queries:
            t0 = time.perf_counter()
            mf.query(q, mode=SWEEP_MODE)
            base_samples.append(time.perf_counter() - t0)

    base_wall = _best_of(per_query_pass)
    base_lat = latency_row(base_samples)
    base_acc = _accuracy(base_answers, queries)
    emit("query_per_query_loop", base_wall / n * 1e6,
         f"qps={n / base_wall:.1f};acc={base_acc:.3f};"
         f"p50_us={base_lat['p50_s'] * 1e6:.0f};"
         f"p99_us={base_lat['p99_s'] * 1e6:.0f}")
    if json_rows is not None:
        json_rows.append({"name": "per_query_loop", "qps": n / base_wall,
                          "us_per_query": base_wall / n * 1e6,
                          "speedup_vs_per_query": 1.0,
                          "parity": 1.0, "acc": base_acc,
                          "p50_s": base_lat["p50_s"],
                          "p99_s": base_lat["p99_s"]})

    for b in SWEEP_BATCHES:
        call_samples: List[float] = []

        def run_batches(b=b, call_samples=call_samples):
            answers: List[str] = []
            for i in range(0, n, b):
                t0 = time.perf_counter()
                rs = mf.query_batch(queries[i:i + b], mode=SWEEP_MODE)
                call_samples.append(time.perf_counter() - t0)
                answers.extend(r.answer for r in rs)
            return answers
        answers = run_batches()
        wall = _best_of(run_batches)
        lat = latency_row(call_samples)        # per query_batch() call
        parity = sum(int(a == bse) for a, bse in zip(answers, base_answers)) / n
        speedup = base_wall / wall
        acc = _accuracy(answers, queries)
        emit(f"query_batch_B{b}", wall / n * 1e6,
             f"qps={n / wall:.1f};speedup_vs_per_query={speedup:.2f}x;"
             f"parity={parity:.3f};acc={acc:.3f};"
             f"p50_us={lat['p50_s'] * 1e6:.0f};p99_us={lat['p99_s'] * 1e6:.0f}")
        if json_rows is not None:
            json_rows.append({"name": f"query_batch_B{b}", "qps": n / wall,
                              "us_per_query": wall / n * 1e6,
                              "speedup_vs_per_query": speedup,
                              "parity": parity, "acc": acc,
                              "batch_call_p50_s": lat["p50_s"],
                              "batch_call_p99_s": lat["p99_s"]})


def _device_sweep(max_devices: int, small: bool,
                  json_path: Optional[str]) -> None:
    """Multi-device serve sweep: fresh system per mesh size, mesh attached
    BEFORE ingest (sharded flush included), ingest sessions/sec + B=64
    query_batch qps per device count, exact parity vs the 1-device row."""
    import jax

    from benchmarks.common import default_workload, emit, fresh_memforest
    from repro.launch.mesh import make_data_mesh

    avail = len(jax.devices())
    counts = [c for c in DEVICE_SWEEP if c <= min(max_devices, avail)]
    if small:
        wl = default_workload(num_entities=4, num_sessions=8,
                              transitions_per_entity=3, num_queries=64)
    else:
        wl = default_workload(num_entities=8, num_sessions=14,
                              transitions_per_entity=4, num_queries=128,
                              seed=2)
    B = 64
    nq = len(wl.queries)
    rows: list = []
    base_answers: Optional[List[str]] = None
    for c in counts:
        mesh = make_data_mesh(c) if c > 1 else None
        got = mesh.devices.size if mesh is not None else 1

        def build():
            mf = fresh_memforest()
            mf.set_mesh(mesh)
            for s in wl.sessions:
                mf.ingest_session(s)
            return mf

        mf = build()                       # warm pass (jit compile)
        ingest_wall = _best_of(build, REPEATS)
        mf.query_batch(wl.queries[:B], mode=SWEEP_MODE)   # warm query path

        def run_queries():
            answers: List[str] = []
            for i in range(0, nq, B):
                answers.extend(r.answer for r in mf.query_batch(
                    wl.queries[i:i + B], mode=SWEEP_MODE))
            return answers
        answers = run_queries()
        wall = _best_of(run_queries)
        if base_answers is None:
            base_answers = answers
        parity = sum(int(a == b) for a, b in zip(answers, base_answers)) / nq
        sess_per_s = len(wl.sessions) / ingest_wall
        qps = nq / wall
        emit(f"query_devices_{c}", wall / nq * 1e6,
             f"devices={got};qps={qps:.1f};ingest_sess_per_s={sess_per_s:.1f};"
             f"parity={parity:.3f}")
        rows.append({"name": f"query_devices_{c}", "devices": got,
                     "qps": qps, "us_per_query": wall / nq * 1e6,
                     "ingest_sess_per_s": sess_per_s, "parity": parity})
        assert parity == 1.0, f"devices={c}: answers diverged from 1-device"
    if json_path:
        from benchmarks.common import write_json
        write_json(json_path, {
            "bench": "query_latency_devices", "mode": SWEEP_MODE,
            "num_queries": nq, "small": small, "batch": B,
            "available_devices": avail, "rows": rows})


def run(small: bool = False, json_path: Optional[str] = None,
        devices: int = 0) -> None:
    if devices > 1:
        _device_sweep(devices, small, json_path)
        return

    from benchmarks.common import (build_systems, default_workload, emit,
                                   fresh_memforest)

    if small:
        wl = default_workload(num_entities=4, num_sessions=8,
                              transitions_per_entity=3, num_queries=48)
        sweep_wl = wl
    else:
        wl = default_workload()
        sweep_wl = default_workload(num_entities=8, num_sessions=14,
                                    transitions_per_entity=4, num_queries=128,
                                    seed=2)

    def bench(system, label, mode=None):
        # warm
        system.query(wl.queries[0]) if mode is None else system.query(wl.queries[0], mode=mode)
        ret = ans = 0.0
        correct = 0
        for q in wl.queries:
            r = system.query(q, mode=mode) if mode is not None else system.query(q)
            ret += r.retrieval_s
            ans += r.answer_s
            correct += int(r.answer.strip().lower() == q.gold.strip().lower())
        n = len(wl.queries)
        emit(f"query_{label}", (ret + ans) / n * 1e6,
             f"retrieval_us={ret/n*1e6:.0f};answer_us={ans/n*1e6:.0f};"
             f"acc={correct/n:.3f}")

    mf = fresh_memforest()
    for s in wl.sessions:
        mf.ingest_session(s)
    bench(mf, "memforest_planner", mode="llm+planner")
    bench(mf, "memforest_emb", mode="emb")

    # batched read path (beyond paper): device-resident normalized indexes +
    # level-synchronous fused browse, swept over serving batch sizes
    json_rows: Optional[list] = [] if json_path else None
    mf_sweep = fresh_memforest()
    for s in sweep_wl.sessions:
        mf_sweep.ingest_session(s)
    _batch_sweep(mf_sweep, sweep_wl.queries, json_rows)
    if json_path:
        from benchmarks.common import write_json
        write_json(json_path, {
            "bench": "query_latency", "mode": SWEEP_MODE,
            "num_queries": len(sweep_wl.queries), "small": small,
            "rows": json_rows})

    if small:
        return
    for name, mk in build_systems().items():
        if name == "memforest":
            continue
        sys_ = mk()
        for s in wl.sessions:
            sys_.ingest_session(s)
        bench(sys_, name)


if __name__ == "__main__":
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="smoke-scale workload (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the sweep rows as JSON")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="multi-device serve sweep on N simulated host "
                         "devices (mesh sizes 1/2/4, parity-checked)")
    args = ap.parse_args()
    if args.devices > 1:
        # must land before the first jax import (run() imports lazily)
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
    print("name,us_per_call,derived")
    run(small=args.small, json_path=args.json, devices=args.devices)
