"""Paper Table 3: query-time latency breakdown (retrieval vs answer) for the
two MemForest operating points and the baselines.

CSV: query_<system>,us_per_query,"retrieval_us=..;answer_us=..;acc=.."
"""
from __future__ import annotations

from benchmarks.common import accuracy, build_systems, default_workload, emit, fresh_memforest


def run() -> None:
    wl = default_workload()

    def bench(system, label, mode=None):
        # warm
        system.query(wl.queries[0]) if mode is None else system.query(wl.queries[0], mode=mode)
        ret = ans = 0.0
        correct = 0
        for q in wl.queries:
            r = system.query(q, mode=mode) if mode is not None else system.query(q)
            ret += r.retrieval_s
            ans += r.answer_s
            correct += int(r.answer.strip().lower() == q.gold.strip().lower())
        n = len(wl.queries)
        emit(f"query_{label}", (ret + ans) / n * 1e6,
             f"retrieval_us={ret/n*1e6:.0f};answer_us={ans/n*1e6:.0f};"
             f"acc={correct/n:.3f}")

    mf = fresh_memforest()
    for s in wl.sessions:
        mf.ingest_session(s)
    bench(mf, "memforest_planner", mode="llm+planner")
    bench(mf, "memforest_emb", mode="emb")

    # batched serving path (beyond-paper): one encoder forward + one fused
    # topk_sim across the whole query batch
    import time as _t
    mf.query_batch(wl.queries[:4], mode="emb")  # warm
    t0 = _t.perf_counter()
    res = mf.query_batch(wl.queries, mode="emb")
    dt = _t.perf_counter() - t0
    correct = sum(int(r.answer.strip().lower() == q.gold.strip().lower())
                  for r, q in zip(res, wl.queries))
    emit("query_memforest_emb_batched", dt / len(wl.queries) * 1e6,
         f"batch={len(wl.queries)};acc={correct/len(wl.queries):.3f}")

    for name, mk in build_systems().items():
        if name == "memforest":
            continue
        sys_ = mk()
        for s in wl.sessions:
            sys_.ingest_session(s)
        bench(sys_, name)


if __name__ == "__main__":
    run()
