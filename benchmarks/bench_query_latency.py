"""Paper Table 3: query-time latency breakdown (retrieval vs answer) for the
two MemForest operating points and the baselines — plus the batched read
path sweep (beyond paper): queries/sec for ``query_batch`` at
B in {1, 8, 32, 64} against the per-query ``query()`` loop, with an answer
parity check (the batched path must be result-identical).

CSV: query_<system>,us_per_query,"retrieval_us=..;answer_us=..;acc=.."
     query_batch_B<k>,us_per_query,"qps=..;speedup_vs_per_query=..;parity=..;acc=.."

``--json PATH`` additionally writes the sweep rows as a JSON document
(BENCH_query.json in CI) so the perf trajectory is tracked across PRs;
``--small`` shrinks the workload for smoke runs.
"""
from __future__ import annotations

import json
import time
from typing import List, Optional

from benchmarks.common import build_systems, default_workload, emit, fresh_memforest

SWEEP_BATCHES = (1, 8, 32, 64)
SWEEP_MODE = "llm+planner"          # the paper's default operating point
REPEATS = 3


def _best_of(fn, repeats: int = REPEATS) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _accuracy(answers, queries) -> float:
    return sum(int(a.strip().lower() == q.gold.strip().lower())
               for a, q in zip(answers, queries)) / max(len(queries), 1)


def _batch_sweep(mf, queries, json_rows: Optional[list]) -> None:
    """Per-query retrieve() loop vs query_batch at each B — identical
    answers required (parity), throughput reported as queries/sec."""
    n = len(queries)
    # warm every jit shape bucket both paths touch
    mf.query(queries[0], mode=SWEEP_MODE)
    for b in SWEEP_BATCHES:
        mf.query_batch(queries[:b], mode=SWEEP_MODE)

    base_answers = [mf.query(q, mode=SWEEP_MODE).answer for q in queries]
    base_wall = _best_of(
        lambda: [mf.query(q, mode=SWEEP_MODE) for q in queries])
    base_acc = _accuracy(base_answers, queries)
    emit("query_per_query_loop", base_wall / n * 1e6,
         f"qps={n / base_wall:.1f};acc={base_acc:.3f}")
    if json_rows is not None:
        json_rows.append({"name": "per_query_loop", "qps": n / base_wall,
                          "us_per_query": base_wall / n * 1e6,
                          "speedup_vs_per_query": 1.0,
                          "parity": 1.0, "acc": base_acc})

    for b in SWEEP_BATCHES:
        def run_batches(b=b):
            answers: List[str] = []
            for i in range(0, n, b):
                answers.extend(
                    r.answer for r in mf.query_batch(queries[i:i + b],
                                                     mode=SWEEP_MODE))
            return answers
        answers = run_batches()
        wall = _best_of(run_batches)
        parity = sum(int(a == bse) for a, bse in zip(answers, base_answers)) / n
        speedup = base_wall / wall
        acc = _accuracy(answers, queries)
        emit(f"query_batch_B{b}", wall / n * 1e6,
             f"qps={n / wall:.1f};speedup_vs_per_query={speedup:.2f}x;"
             f"parity={parity:.3f};acc={acc:.3f}")
        if json_rows is not None:
            json_rows.append({"name": f"query_batch_B{b}", "qps": n / wall,
                              "us_per_query": wall / n * 1e6,
                              "speedup_vs_per_query": speedup,
                              "parity": parity, "acc": acc})


def run(small: bool = False, json_path: Optional[str] = None) -> None:
    if small:
        wl = default_workload(num_entities=4, num_sessions=8,
                              transitions_per_entity=3, num_queries=48)
        sweep_wl = wl
    else:
        wl = default_workload()
        sweep_wl = default_workload(num_entities=8, num_sessions=14,
                                    transitions_per_entity=4, num_queries=128,
                                    seed=2)

    def bench(system, label, mode=None):
        # warm
        system.query(wl.queries[0]) if mode is None else system.query(wl.queries[0], mode=mode)
        ret = ans = 0.0
        correct = 0
        for q in wl.queries:
            r = system.query(q, mode=mode) if mode is not None else system.query(q)
            ret += r.retrieval_s
            ans += r.answer_s
            correct += int(r.answer.strip().lower() == q.gold.strip().lower())
        n = len(wl.queries)
        emit(f"query_{label}", (ret + ans) / n * 1e6,
             f"retrieval_us={ret/n*1e6:.0f};answer_us={ans/n*1e6:.0f};"
             f"acc={correct/n:.3f}")

    mf = fresh_memforest()
    for s in wl.sessions:
        mf.ingest_session(s)
    bench(mf, "memforest_planner", mode="llm+planner")
    bench(mf, "memforest_emb", mode="emb")

    # batched read path (beyond paper): device-resident normalized indexes +
    # level-synchronous fused browse, swept over serving batch sizes
    json_rows: Optional[list] = [] if json_path else None
    mf_sweep = fresh_memforest()
    for s in sweep_wl.sessions:
        mf_sweep.ingest_session(s)
    _batch_sweep(mf_sweep, sweep_wl.queries, json_rows)
    if json_path:
        doc = {"bench": "query_latency", "mode": SWEEP_MODE,
               "num_queries": len(sweep_wl.queries), "small": small,
               "rows": json_rows}
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_path}", flush=True)

    if small:
        return
    for name, mk in build_systems().items():
        if name == "memforest":
            continue
        sys_ = mk()
        for s in wl.sessions:
            sys_.ingest_session(s)
        bench(sys_, name)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="smoke-scale workload (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the batch-sweep rows as JSON")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(small=args.small, json_path=args.json)
