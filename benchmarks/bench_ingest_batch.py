"""Batched multi-session ingestion throughput (the cross-tenant write path).

For each batch size B in {1, 4, 16, 64}: build a fresh system, ingest the
same stream of sessions through ``ingest_batch`` in B-sized batches, and
report sessions/sec plus the speedup over the sequential per-session loop
(B = 1 through the same code path, and the classic ``ingest_session`` loop
as the reference row). The hashing encoder is used so timings measure the
SYSTEM: encoder-forward count, canonicalization passes, and flush/kernel
launches per session, not model FLOPs.

CSV: ingest_batch_B<k>,us_per_session,
     "sess_per_s=..;speedup_vs_b1=..;enc_calls=..;flush_calls=.."

``--devices N`` switches to the multi-device serve sweep instead: forces
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before any jax import
(which is why the jax-touching imports live inside the functions), then
reports batched-ingest sessions/sec per mesh size in {1, 2, 4} (capped at
N), with sharded flush refresh batches riding the mesh. Host-simulated
devices share one CPU — this measures sharding overhead, not real scaling.

CSV: ingest_devices_<c>,us_per_session,"devices=..;sess_per_s=.."
"""
from __future__ import annotations

import time
from typing import List

BATCH_SIZES = (1, 4, 16, 64)
DEVICE_SWEEP = (1, 2, 4)
NUM_SESSIONS = 256
REPEATS = 3


def _sessions(n: int = NUM_SESSIONS) -> List:
    from benchmarks.common import default_workload

    wl = default_workload(num_entities=16, num_sessions=n,
                          transitions_per_entity=10, num_queries=0, seed=3)
    return wl.sessions[:n]


def _measure(sessions, batch: int, ingest) -> dict:
    from benchmarks.common import fresh_memforest
    """Shared protocol for every row: one untimed warm pass on a throwaway
    system compiles every jit shape bucket this config touches (the jit
    caches are process-global); then fresh systems are timed REPEATS times
    and the best wall is kept (robust to scheduler noise). ``ingest`` is
    called as ingest(system, chunk_of_sessions) per batch slice."""
    warm = fresh_memforest()
    for i in range(0, len(sessions), batch):
        ingest(warm, sessions[i:i + batch])
    wall = float("inf")
    for _ in range(REPEATS):
        sys_ = fresh_memforest()
        t0 = time.perf_counter()
        for i in range(0, len(sessions), batch):
            ingest(sys_, sessions[i:i + batch])
        wall = min(wall, time.perf_counter() - t0)
    return dict(wall=wall, n=len(sessions), enc_calls=sys_.encoder.stats.calls,
                flush_calls=sys_.forest.flush_calls)


def _ingest_batched(sessions, batch: int) -> dict:
    return _measure(sessions, batch, lambda s, chunk: s.ingest_batch(chunk))


def _device_sweep(max_devices: int) -> None:
    """Batched ingest throughput per serve-mesh size: a fresh system per
    count with the mesh attached before the first session, so the flush's
    sharded tree_refresh path is what gets timed."""
    import jax

    from benchmarks.common import emit, fresh_memforest
    from repro.launch.mesh import make_data_mesh

    avail = len(jax.devices())
    counts = [c for c in DEVICE_SWEEP if c <= min(max_devices, avail)]
    sessions = _sessions(64)
    batch = 16
    for c in counts:
        mesh = make_data_mesh(c) if c > 1 else None
        got = mesh.devices.size if mesh is not None else 1

        def ingest(s, chunk):
            s.ingest_batch(chunk)

        def fresh():
            mf = fresh_memforest()
            mf.set_mesh(mesh)
            return mf

        warm = fresh()
        for i in range(0, len(sessions), batch):
            ingest(warm, sessions[i:i + batch])
        wall = float("inf")
        for _ in range(REPEATS):
            sys_ = fresh()
            t0 = time.perf_counter()
            for i in range(0, len(sessions), batch):
                ingest(sys_, sessions[i:i + batch])
            wall = min(wall, time.perf_counter() - t0)
        n = len(sessions)
        emit(f"ingest_devices_{c}", wall / n * 1e6,
             f"devices={got};sess_per_s={n / wall:.1f}")


def run(devices: int = 0) -> None:
    if devices > 1:
        _device_sweep(devices)
        return

    from benchmarks.common import emit

    sessions = _sessions()

    # reference: the classic sequential ingest loop (same protocol)
    seq = _measure(sessions, 1, lambda s, chunk: s.ingest_session(chunk[0]))
    n = seq["n"]
    emit("ingest_sequential_loop", seq["wall"] / n * 1e6,
         f"sess_per_s={n / seq['wall']:.1f};enc_calls={seq['enc_calls']};"
         f"flush_calls={seq['flush_calls']}")

    results = {}
    for b in BATCH_SIZES:
        results[b] = _ingest_batched(sessions, b)
    base = results[1]
    for b in BATCH_SIZES:
        r = results[b]
        rate = r["n"] / r["wall"]
        speedup = (base["wall"] / base["n"]) / (r["wall"] / r["n"])
        emit(f"ingest_batch_B{b}", r["wall"] / r["n"] * 1e6,
             f"sess_per_s={rate:.1f};speedup_vs_b1={speedup:.2f}x;"
             f"enc_calls={r['enc_calls']};flush_calls={r['flush_calls']}")


if __name__ == "__main__":
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="multi-device serve sweep on N simulated host "
                         "devices (mesh sizes 1/2/4)")
    args = ap.parse_args()
    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
    run(devices=args.devices)
