"""Batched multi-session ingestion throughput (the cross-tenant write path).

For each batch size B in {1, 4, 16, 64}: build a fresh system, ingest the
same stream of sessions through ``ingest_batch`` in B-sized batches, and
report sessions/sec plus the speedup over the sequential per-session loop
(B = 1 through the same code path, and the classic ``ingest_session`` loop
as the reference row). The hashing encoder is used so timings measure the
SYSTEM: encoder-forward count, canonicalization passes, and flush/kernel
launches per session, not model FLOPs.

CSV: ingest_batch_B<k>,us_per_session,
     "sess_per_s=..;speedup_vs_b1=..;enc_calls=..;flush_calls=.."
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import default_workload, fresh_memforest, emit

BATCH_SIZES = (1, 4, 16, 64)
NUM_SESSIONS = 256
REPEATS = 3


def _sessions() -> List:
    wl = default_workload(num_entities=16, num_sessions=NUM_SESSIONS,
                          transitions_per_entity=10, num_queries=0, seed=3)
    return wl.sessions[:NUM_SESSIONS]


def _measure(sessions, batch: int, ingest) -> dict:
    """Shared protocol for every row: one untimed warm pass on a throwaway
    system compiles every jit shape bucket this config touches (the jit
    caches are process-global); then fresh systems are timed REPEATS times
    and the best wall is kept (robust to scheduler noise). ``ingest`` is
    called as ingest(system, chunk_of_sessions) per batch slice."""
    warm = fresh_memforest()
    for i in range(0, len(sessions), batch):
        ingest(warm, sessions[i:i + batch])
    wall = float("inf")
    for _ in range(REPEATS):
        sys_ = fresh_memforest()
        t0 = time.perf_counter()
        for i in range(0, len(sessions), batch):
            ingest(sys_, sessions[i:i + batch])
        wall = min(wall, time.perf_counter() - t0)
    return dict(wall=wall, n=len(sessions), enc_calls=sys_.encoder.stats.calls,
                flush_calls=sys_.forest.flush_calls)


def _ingest_batched(sessions, batch: int) -> dict:
    return _measure(sessions, batch, lambda s, chunk: s.ingest_batch(chunk))


def run() -> None:
    sessions = _sessions()

    # reference: the classic sequential ingest loop (same protocol)
    seq = _measure(sessions, 1, lambda s, chunk: s.ingest_session(chunk[0]))
    n = seq["n"]
    emit("ingest_sequential_loop", seq["wall"] / n * 1e6,
         f"sess_per_s={n / seq['wall']:.1f};enc_calls={seq['enc_calls']};"
         f"flush_calls={seq['flush_calls']}")

    results = {}
    for b in BATCH_SIZES:
        results[b] = _ingest_batched(sessions, b)
    base = results[1]
    for b in BATCH_SIZES:
        r = results[b]
        rate = r["n"] / r["wall"]
        speedup = (base["wall"] / base["n"]) / (r["wall"] / r["n"])
        emit(f"ingest_batch_B{b}", r["wall"] / r["n"] * 1e6,
             f"sess_per_s={rate:.1f};speedup_vs_b1={speedup:.2f}x;"
             f"enc_calls={r['enc_calls']};flush_calls={r['flush_calls']}")


if __name__ == "__main__":
    run()
