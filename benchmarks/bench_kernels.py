"""Kernel microbenchmarks: reference (XLA) wall time on CPU + interpret-mode
correctness deltas. On real TPUs the same harness times the Pallas path.

CSV: kernel_<name>,us_per_call,"max_err_vs_ref=..;shape=.."
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops


def _bench(fn, *args, repeats=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def _err(a, b):
    fa = np.asarray(jax.tree.leaves(a)[0], np.float32)
    fb = np.asarray(jax.tree.leaves(b)[0], np.float32)
    return float(np.max(np.abs(fa - fb)))


def run() -> None:
    rng = np.random.default_rng(0)

    B, S, Hq, Hkv, D = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    t = _bench(ops.attention, q, k, v, impl="reference")
    e = _err(ops.attention(q, k, v, impl="reference"),
             ops.attention(q, k, v, impl="pallas_interpret", block_q=256, block_kv=256))
    emit("kernel_flash_attention", t * 1e6, f"max_err_vs_ref={e:.2e};shape=B{B}xS{S}xH{Hq}xD{D}")

    Smax = 4096
    kc = jnp.asarray(rng.normal(size=(B, Smax, Hkv, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, Smax, Hkv, D)), jnp.float32)
    qd = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    lens = jnp.asarray([Smax - 3], jnp.int32)
    t = _bench(ops.decode_attention, qd, kc, vc, lens, impl="reference")
    e = _err(ops.decode_attention(qd, kc, vc, lens, impl="reference"),
             ops.decode_attention(qd, kc, vc, lens, impl="pallas_interpret", block_kv=512))
    emit("kernel_decode_attention", t * 1e6, f"max_err_vs_ref={e:.2e};shape=S{Smax}")

    Q, N, Dd, K = 8, 8192, 256, 10
    qq = jnp.asarray(rng.normal(size=(Q, Dd)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(N, Dd)), jnp.float32)
    t = _bench(ops.topk_sim, qq, kk, K, impl="reference")
    r1 = ops.topk_sim(qq, kk, K, impl="reference")
    r2 = ops.topk_sim(qq, kk, K, impl="pallas_interpret")
    e = _err(r1[0], r2[0])
    emit("kernel_topk_sim", t * 1e6, f"max_err_vs_ref={e:.2e};shape=Q{Q}xN{N}xK{K}")

    P, Kk = 256, 8
    ce = jnp.asarray(rng.normal(size=(P, Kk, Dd)), jnp.float32)
    cm = jnp.asarray(rng.random((P, Kk)) > 0.3)
    t = _bench(ops.tree_refresh, ce, cm, impl="reference")
    e = _err(ops.tree_refresh(ce, cm, impl="reference"),
             ops.tree_refresh(ce, cm, impl="pallas_interpret"))
    emit("kernel_tree_refresh", t * 1e6, f"max_err_vs_ref={e:.2e};shape=P{P}xK{Kk}xD{Dd}")

    B2, T, H, Kh, V2 = 1, 512, 4, 64, 64
    r = jnp.asarray(rng.normal(size=(B2, T, H, Kh)) * .5, jnp.float32)
    kx = jnp.asarray(rng.normal(size=(B2, T, H, Kh)) * .5, jnp.float32)
    vx = jnp.asarray(rng.normal(size=(B2, T, H, V2)) * .5, jnp.float32)
    w = jnp.asarray(rng.normal(size=(B2, T, H, Kh)) * .5, jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, Kh)) * .5, jnp.float32)
    s0 = jnp.zeros((B2, H, Kh, V2), jnp.float32)
    t = _bench(ops.rwkv6_scan, r, kx, vx, w, u, s0, impl="reference")
    o1 = ops.rwkv6_scan(r, kx, vx, w, u, s0, impl="reference")
    o2 = ops.rwkv6_scan(r, kx, vx, w, u, s0, impl="pallas_interpret")
    emit("kernel_rwkv6_scan", t * 1e6,
         f"max_err_vs_ref={_err(o1[0], o2[0]):.2e};shape=T{T}xH{H}xK{Kh}")

    Pd, Nd = 64, 64
    x = jnp.asarray(rng.normal(size=(B2, T, H, Pd)), jnp.float32)
    dt = jnp.asarray(rng.random((B2, T, H)) * .5 + .01, jnp.float32)
    A = -jnp.asarray(rng.random((H,)) + .1, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B2, T, Nd)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B2, T, Nd)), jnp.float32)
    sm = jnp.zeros((B2, H, Pd, Nd), jnp.float32)
    t = _bench(ops.mamba2_ssd, x, dt, A, Bm, C, sm, impl="reference")
    y1 = ops.mamba2_ssd(x, dt, A, Bm, C, sm, impl="reference")
    y2 = ops.mamba2_ssd(x, dt, A, Bm, C, sm, impl="pallas_interpret")
    emit("kernel_mamba2_ssd", t * 1e6,
         f"max_err_vs_ref={_err(y1[0], y2[0]):.2e};shape=T{T}xH{H}xP{Pd}xN{Nd}")


if __name__ == "__main__":
    run()
