"""Lifecycle maintenance demo: migration merge vs sequential write, plus
targeted deletion (paper §5.6, Figure 5).

    PYTHONPATH=src python examples/migration_merge.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

from repro.config import MemForestConfig
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload


def build(sessions):
    mf = MemForestSystem(MemForestConfig())
    for s in sessions:
        mf.ingest_session(s)
    return mf


# two independently-built memory instances (e.g. two assistants' stores)
wa = make_workload(num_entities=3, num_sessions=5, num_queries=1, seed=11)
wb = make_workload(num_entities=3, num_sessions=5, num_queries=1, seed=22)

print("building instance A and B independently ...")
a = build(wa.sessions)
b = build(wb.sessions)
print("A:", a.scale_stats())
print("B:", b.scale_stats())

# migration merge: NO raw-session replay
t0 = time.perf_counter()
stats = a.merge_from(b)
t_merge = time.perf_counter() - t0
print(f"\nmigration merge in {t_merge*1e3:.0f}ms: {stats}")
print("merged:", a.scale_stats())

# sequential-write reference
t0 = time.perf_counter()
seq = build(wa.sessions + wb.sessions)
t_seq = time.perf_counter() - t0
print(f"sequential rebuild in {t_seq*1e3:.0f}ms "
      f"-> migration speedup {t_seq/t_merge:.1f}x")
print("sequential:", seq.scale_stats())

# targeted deletion: only affected paths refresh
sid = wa.sessions[0].session_id
before = a.forest.summary_refreshes
d = a.delete_session(sid)
print(f"\ndeleted session {sid}: {d} "
      f"({a.forest.summary_refreshes - before} summary refreshes)")
print("after delete:", a.scale_stats())
