"""Train a reduced LM for a few hundred steps on CPU, with checkpointing and
restart (the training substrate end-to-end).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.configs import get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.data.synthetic import make_workload
from repro.models import get_model
from repro.runtime import checkpoint as ckpt
from repro.training.train_loop import init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="llama3_8b")
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
model = get_model(cfg)
tcfg = TrainConfig(learning_rate=1e-3, total_steps=args.steps,
                   warmup_steps=10, microbatch_size=2)
print(f"training {cfg.name}: {cfg.param_count():,} params, {args.steps} steps")

# corpus: the synthetic session stream's text (what the memory system stores)
wl = make_workload(num_entities=8, num_sessions=20, num_queries=1, seed=0)
corpus = [t.text for s in wl.sessions for t in s.turns]
pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                     corpus=corpus)

state = init_train_state(model, tcfg, jax.random.key(0))
step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
ckpt_dir = tempfile.mkdtemp(prefix="repro_train_")

t0 = time.perf_counter()
for step in range(args.steps):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
    state, metrics = step_fn(state, batch)
    if step % 20 == 0 or step == args.steps - 1:
        tps = (step + 1) * 8 * 64 / (time.perf_counter() - t0)
        print(f"step {step:4d}  loss {float(metrics['loss']):7.4f}  "
              f"lr {float(metrics['lr']):.2e}  {tps:,.0f} tok/s")
    if (step + 1) % 100 == 0:
        ckpt.save(ckpt_dir, step + 1, state, extra={"step": step + 1})

# restart check: restore and confirm training state round-trips
latest = ckpt.latest_step(ckpt_dir)
if latest:
    restored, extra = ckpt.restore(ckpt_dir, state)
    print(f"checkpoint restart OK at step {extra['step']}")
print("done.")
