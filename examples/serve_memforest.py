"""End-to-end driver: serve a small model with batched requests as the
MemForest builder/answerer backbone (the paper's deployment shape).

A real LM from the zoo (reduced llama3 config) handles:
  * chunk-embedding for extraction (batched forward = parallel write path),
  * query/summary embeddings for retrieval,
while the serving engine demonstrates continuous batching on the same model.

    PYTHONPATH=src python examples/serve_memforest.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro.config import MemForestConfig
from repro.configs import get_smoke_config
from repro.core.encoder import ModelEncoder
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload
from repro.data.tokenizer import HashTokenizer
from repro.models import get_model
from repro.serving.engine import ServeEngine

# --- backbone: a real (reduced) zoo model -----------------------------------
cfg = get_smoke_config("llama3_8b").replace(d_model=128, num_heads=4,
                                            num_kv_heads=4, head_dim=32,
                                            num_layers=2)
model = get_model(cfg)
params = model.init(jax.random.key(0))
print(f"backbone: {cfg.name} ({cfg.param_count():,} params)")

tok = HashTokenizer(cfg.vocab_size)
encoder = ModelEncoder(cfg, params, tok, max_len=64)

# --- build memory over a synthetic long-horizon workload --------------------
wl = make_workload(num_entities=4, num_sessions=6, transitions_per_entity=3,
                   num_queries=12, seed=0)
mf = MemForestSystem(MemForestConfig(embed_dim=cfg.d_model), encoder)

t0 = time.perf_counter()
for s in wl.sessions:
    mf.ingest_session(s)
print(f"write path: {time.perf_counter()-t0:.2f}s for {len(wl.sessions)} sessions "
      f"({encoder.stats.calls} batched model calls)")
print("memory:", mf.scale_stats())

# batched read path: one encoder forward + fused index scans + one browse
# launch per tree level for ALL queries (device-resident normalized indexes)
t0 = time.perf_counter()
results = mf.query_batch(wl.queries)
dt = time.perf_counter() - t0
correct = sum(int(r.answer.strip().lower() == q.gold.strip().lower())
              for r, q in zip(results, wl.queries))
print(f"read path: {dt:.2f}s for {len(wl.queries)} queries (batched) | "
      f"answer accuracy: {correct}/{len(wl.queries)}")

# --- batched request serving on the same backbone ----------------------------
print("\nserving engine (continuous batching, decode + query lanes):")
eng = ServeEngine(model, params, max_batch=4, max_len=64, memory=mf)
rng = np.random.default_rng(0)
for i in range(8):
    eng.submit(tok.encode(f"summarize interval {i} of the bob residence scope"),
               max_new_tokens=4, prefix_key="summarize")
rids = [eng.submit_query(q) for q in wl.queries]   # retrieval rides the loop
t0 = time.perf_counter()
done = eng.run_until_drained()
dt = time.perf_counter() - t0
m = eng.metrics()
print(f"served {len(done)} decode requests + {m['queries_served']:.0f} queries "
      f"in {dt:.2f}s | occupancy {m['mean_occupancy']:.0%} | "
      f"{m['decoded_tokens']} tokens | query batches {m['query_batches']:.0f}")
assert all(eng.pop_query_result(r).answer == res.answer
           for r, res in zip(rids, results))

# --- multi-tenant over-subscription through the residency tier ---------------
# Six tenants on a hot budget of two: the engine routes tenant-tagged
# sessions/queries through the ResidencyManager — cold tenants rehydrate
# inside the drains (or answer from their always-resident digest), and
# traffic-aware LRU demotion runs on the residency lane after each decode
# step, never on it.
import tempfile

from repro.core.residency import ResidencyConfig, ResidencyManager

print("\nresidency tier (6 tenants, hot budget 2, transparent rehydration):")
mgr = ResidencyManager(tempfile.mkdtemp(prefix="memforest_tenants_"),
                       config=ResidencyConfig(hot_budget=2),
                       mem_config=MemForestConfig())
teng = ServeEngine(model, params, max_batch=4, max_len=64, residency=mgr)
tenant_wls = {f"tenant{i}": make_workload(num_entities=2, num_sessions=3,
                                          transitions_per_entity=3,
                                          num_queries=6, seed=100 + i)
              for i in range(6)}
for tid, twl in tenant_wls.items():
    for s in twl.sessions:
        teng.submit_session(s, tenant=tid)
for i in range(4):                              # decode traffic rides along
    teng.submit(tok.encode(f"tenant status {i}"), max_new_tokens=3)
trids = {tid: [teng.submit_query(q, tenant=tid) for q in twl.queries]
         for tid, twl in tenant_wls.items()}
t0 = time.perf_counter()
teng.run_until_drained()
dt = time.perf_counter() - t0
served = sum(int(teng.pop_query_result(r) is not None)
             for rs in trids.values() for r in rs)
m = teng.metrics()
print(f"served {served} tenant queries across {m['tenants']} tenants "
      f"in {dt:.2f}s | hot {m['hot_tenants']}/{m['hot_budget']} | "
      f"evictions {m['evictions']} | rehydrations {m['rehydrations']} | "
      f"digest answers {m['digest_answers']} | "
      f"device bytes {m['device_bytes_est']:,} "
      f"(digests {m['digest_bytes']:,})")
assert m["hot_tenants"] <= 2
mgr.close()
