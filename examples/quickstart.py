"""Quickstart: build agent memory from a conversation stream, then query it.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.config import MemForestConfig
from repro.core.memforest import MemForestSystem
from repro.core.types import Query, Session, Turn

# --- the paper's running example (§2.3.3) -----------------------------------
sessions = [
    Session("s1", [
        Turn("user", "Bob lives in Boston as of January 2023.", 36.0, 0),
        Turn("assistant", "Noted, thanks for sharing.", 36.0, 1),
        Turn("user", "Bob moved from Boston to Davis in May 2023.", 40.0, 2),
        Turn("assistant", "Got it.", 40.0, 3),
    ]),
    Session("s2", [
        Turn("user", "The weather has been quite nice lately.", 50.0, 0),
        Turn("assistant", "Indeed it has.", 50.0, 1),
        Turn("user", "Bob moved from Davis to Miami in July 2024.", 54.0, 2),
        Turn("assistant", "Understood.", 54.0, 3),
    ]),
    Session("s3", [
        Turn("user", "Bob's favorite thing is green tea as of August 2024.", 56.0, 0),
        Turn("assistant", "Noted.", 56.0, 1),
    ]),
]

mf = MemForestSystem(MemForestConfig())
for s in sessions:
    stats = mf.ingest_session(s)
    print(f"ingested {s.session_id}: +{stats.facts_written} facts, "
          f"dependency depth {stats.llm_dependency_depth}")

print("\nmemory state:", mf.scale_stats())

queries = [
    Query("Where does Bob live now?", "current", "Bob", "residence"),
    Query("Where did Bob live before moving to Miami?", "historical",
          "Bob", "residence", anchor_value="Miami"),
    Query("When did Bob move to Miami?", "transition_time",
          "Bob", "residence", anchor_value="Miami"),
    Query("What was the first place Bob lived in?", "multi_session",
          "Bob", "residence"),
]
print()
for q in queries:
    r = mf.query(q)
    print(f"Q: {q.text}\nA: {r.answer}   (evidence: {r.evidence[0][:60]}...)\n")
