import os
import sys

# tests see ONE device (the dry-run subprocess sets its own XLA_FLAGS)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
