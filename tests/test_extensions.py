"""Beyond-paper extensions: read-triggered refresh, canonicalization
properties, elastic re-mesh integration, parser robustness."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _propcheck import given, settings, strategies as st

from repro.config import MemForestConfig
from repro.core.canonical import canonicalize
from repro.core.forest import Forest
from repro.core.memforest import MemForestSystem
from repro.core.types import RawCandidate
from repro.data.synthetic import make_workload

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# read-triggered lazy refresh
# ---------------------------------------------------------------------------
def test_read_triggered_refresh_defers_flush():
    wl = make_workload(num_entities=4, num_sessions=6, num_queries=10, seed=4)
    deferred = MemForestSystem(MemForestConfig(read_triggered_refresh=True))
    eagerly = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        deferred.ingest_session(s)
        eagerly.ingest_session(s)
    # ingestion did NOT flush: dirty trees pending, fewer refreshes so far
    assert deferred.forest.dirty_trees
    assert deferred.forest.summary_refreshes < eagerly.forest.summary_refreshes
    # first query pays the flush and answers identically
    for q in wl.queries:
        a = deferred.query(q).answer
        b = eagerly.query(q).answer
        assert a == b
    assert not deferred.forest.dirty_trees


# ---------------------------------------------------------------------------
# canonicalization properties
# ---------------------------------------------------------------------------
def _cand(subj, attr, val, ts, src=("s0", 0)):
    return RawCandidate(
        text=f"{subj} {attr} {val} at {ts}", subject=subj, attribute=attr,
        value=val, ts=ts, prev_value=None, source=src,
    )


@settings(max_examples=40, deadline=None)
@given(dup=st.integers(1, 6), nsub=st.integers(1, 4))
def test_canonicalize_dedup_idempotent(dup, nsub):
    """Exact duplicates collapse to one fact with merged sources; running
    canonicalize twice adds nothing (idempotence)."""
    forest = Forest(MemForestConfig(embed_dim=16))
    rng = np.random.default_rng(0)
    cands = []
    for i in range(nsub):
        for d in range(dup):
            cands.append(_cand(f"Sub{i}", "residence", "Miami", 5.0, (f"s{d}", d)))
    embs = rng.normal(size=(len(cands), 16)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)
    new1 = canonicalize(cands, embs, forest)
    assert len(new1) == nsub
    for f in new1:
        assert len(f.sources) == dup
    new2 = canonicalize(cands, embs, forest)
    assert len(new2) == 0  # idempotent vs existing store


def test_canonicalize_distinct_timestamps_kept():
    forest = Forest(MemForestConfig(embed_dim=16))
    cands = [_cand("Bob", "residence", "Miami", t) for t in (1.0, 5.0, 9.0)]
    embs = np.eye(16, dtype=np.float32)[:3]
    new = canonicalize(cands, embs, forest)
    assert len(new) == 3  # same value, different anchors = history, not dupes


# ---------------------------------------------------------------------------
# elastic re-mesh: replan -> re-lower on the smaller mesh (smoke, subprocess)
# ---------------------------------------------------------------------------
def test_elastic_replan_relowers(tmp_path):
    from repro.runtime.fault_tolerance import ElasticScaler
    ladder = ElasticScaler()
    assert ladder.replan(300) == ((16, 16), ("data", "model"))
    # prove the smaller smoke mesh actually lowers+compiles after "losing"
    # devices (8 -> 4): run the dryrun smoke path on a (2,2) mesh
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4';"
        "import sys; sys.path.insert(0,'src');"
        "from repro.configs import get_smoke_config;"
        "from repro.configs.shapes import SHAPES;"
        "from repro.config import TrainConfig;"
        "import dataclasses;"
        "from repro.launch.mesh import make_mesh;"
        "from repro.launch.dryrun import run_cell;"
        "shape=dataclasses.replace(SHAPES['train_4k'],seq_len=64,global_batch=4);"
        "r=run_cell('llama3_8b','train_4k','single',cfg_override=get_smoke_config('llama3_8b'),"
        "shape_override=shape,mesh_override=make_mesh((2,2),('data','model')));"
        "assert r['ok'], r;"
        "print('ELASTIC_OK')"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, cwd=ROOT)
    assert "ELASTIC_OK" in out.stdout, out.stdout[-1500:] + out.stderr[-1500:]


# ---------------------------------------------------------------------------
# HLO parser robustness
# ---------------------------------------------------------------------------
def test_hlo_parser_tolerates_garbage():
    from repro.launch.hlo_analysis import collective_bytes
    assert collective_bytes("")["total"] == 0
    assert collective_bytes("not hlo at all\n{}{}")["total"] == 0
    nested = """
cond_a (p: (s32[])) -> pred[] {
  %c = s32[] constant(3)
}
body_inner (p: (s32[])) -> (s32[]) {
  %ar = f32[10]{0} all-reduce(%x), replica_groups=[1,2]<=[2]
}
cond_b (p: (s32[])) -> pred[] {
  %c2 = s32[] constant(4)
}
body_outer (p: (s32[])) -> (s32[]) {
  %w2 = (s32[]) while(%t), condition=%cond_a, body=%body_inner
}
ENTRY main (p: f32[10]) -> f32[10] {
  %w = (s32[]) while(%t0), condition=%cond_b, body=%body_outer
}
"""
    out = collective_bytes(nested)
    assert out["all-reduce"] == 4 * 3 * 40  # nested trip counts multiply
