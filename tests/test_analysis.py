"""memlint (repro/analysis): every rule has a triggering fixture and a
clean-pass fixture, suppression/baseline semantics are pinned, the CLI exit
codes are pinned, and the real tree sweeps clean with an EMPTY baseline."""
import json
import os
import textwrap

from repro.analysis import RULES, run_paths
from repro.analysis.__main__ import main as memlint_main

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def sweep(tmp_path, files, rules=None, baseline=None):
    """Materialize ``{relpath: source}`` under tmp_path and sweep its src/."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_paths([str(tmp_path / "src")], rules=rules,
                     repo_root=str(tmp_path), baseline=baseline)


def rule_ids(res):
    return [f.rule for f in res.findings]


def test_registry_has_the_seven_invariant_rules():
    assert {"topk-tiebreak", "rename-fsync", "journaled-mutation",
            "replay-determinism", "span-context", "kernel-parity",
            "host-sync"} <= set(RULES)


# ---------------------------------------------------------------------------
# rule 1: deterministic top-k tie-break
# ---------------------------------------------------------------------------
def test_topk_tiebreak_triggers(tmp_path):
    res = sweep(tmp_path, {"src/repro/core/retrieval.py": """
        import numpy as np
        import jax

        def pick(sims, k):
            a = np.argsort(-sims)[:k]
            b = jax.lax.top_k(sims, k)
            return a, b
    """}, rules=["topk-tiebreak"])
    assert rule_ids(res) == ["topk-tiebreak", "topk-tiebreak"]
    assert res.findings[0].line == 6 and res.findings[1].line == 7


def test_topk_tiebreak_clean_and_scoped(tmp_path):
    res = sweep(tmp_path, {
        "src/repro/core/retrieval.py": """
            import numpy as np
            import jax.numpy as jnp

            def pick(sims, k):
                a = np.argsort(-sims, kind="stable")[:k]
                b = jnp.argsort(-sims, stable=True)[:k]
                return a, b
        """,
        # bare argsort outside the scoped files is not this rule's business
        "src/repro/data/synthetic.py": """
            import numpy as np

            def shuffle_order(x):
                return np.argsort(x)
        """}, rules=["topk-tiebreak"])
    assert res.clean


# ---------------------------------------------------------------------------
# rule 2: rename followed by fsync_dir
# ---------------------------------------------------------------------------
def test_rename_fsync_triggers(tmp_path):
    res = sweep(tmp_path, {"src/repro/core/store.py": """
        import os

        def commit(tmp, final):
            os.replace(tmp, final)
    """}, rules=["rename-fsync"])
    assert rule_ids(res) == ["rename-fsync"]
    assert "fsync_dir" in res.findings[0].message


def test_rename_fsync_clean(tmp_path):
    res = sweep(tmp_path, {"src/repro/core/store.py": """
        import os

        def fsync_dir(path):
            fd = os.open(path, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)

        def commit(tmp, final):
            os.replace(tmp, final)
            fsync_dir(os.path.dirname(final))
    """}, rules=["rename-fsync"])
    assert res.clean


# ---------------------------------------------------------------------------
# rule 3: persistent mutations ride the journal
# ---------------------------------------------------------------------------
def test_journaled_mutation_triggers(tmp_path):
    res = sweep(tmp_path, {"src/repro/core/plane.py": """
        from repro.core import maintenance
        from repro.core.maintenance import delete_session

        def tick(forest, src, sid):
            maintenance.migrate_merge(forest, src)
            delete_session(forest, sid)
    """}, rules=["journaled-mutation"])
    assert rule_ids(res) == ["journaled-mutation"] * 2


def test_journaled_mutation_allows_journal_module_and_durable_ops(tmp_path):
    res = sweep(tmp_path, {
        # journal.py IS the journaled path — exempt
        "src/repro/core/journal.py": """
            from repro.core import maintenance

            def _apply(forest, src):
                maintenance.migrate_merge(forest, src)
        """,
        # routing through the DurableMemForest op is the sanctioned shape
        "src/repro/core/plane.py": """
            def tick(store, scope):
                store.compact_tree(scope, idempotency_key="k")
        """}, rules=["journaled-mutation"])
    assert res.clean


# ---------------------------------------------------------------------------
# rule 4: replay / digest determinism
# ---------------------------------------------------------------------------
def test_replay_determinism_triggers(tmp_path):
    res = sweep(tmp_path, {"src/repro/core/journal.py": """
        import random
        import time

        def replay(forest, recs):
            t0 = time.time()
            random.shuffle(recs)
            for op in forest.applied_ops:
                pass
            return t0
    """}, rules=["replay-determinism"])
    assert sorted(rule_ids(res)) == ["replay-determinism"] * 3
    msgs = " ".join(f.message for f in res.findings)
    assert "time.time" in msgs and "random." in msgs and "set" in msgs


def test_replay_determinism_clean_when_sorted_and_out_of_scope(tmp_path):
    res = sweep(tmp_path, {
        "src/repro/core/journal.py": """
            def replay(forest, recs):
                for op in sorted(forest.applied_ops):
                    pass
        """,
        # wall clocks are fine outside replay/serialization modules
        "src/repro/serving/engine.py": """
            import time

            def now():
                return time.time()
        """}, rules=["replay-determinism"])
    assert res.clean


# ---------------------------------------------------------------------------
# rule 5: spans only via context manager
# ---------------------------------------------------------------------------
def test_span_context_triggers(tmp_path):
    res = sweep(tmp_path, {"src/repro/serving/engine.py": """
        def step(obs):
            s = obs.span("engine.step")
            s.__enter__()
    """}, rules=["span-context"])
    assert "span-context" in rule_ids(res)
    assert any("__enter__" in f.message for f in res.findings)


def test_span_context_clean_with_statement_and_obs_layer(tmp_path):
    res = sweep(tmp_path, {
        "src/repro/serving/engine.py": """
            def step(obs):
                with obs.span("engine.step"):
                    pass
        """,
        # the obs implementation layer itself may touch span internals
        "src/repro/obs/trace.py": """
            def span(self, name):
                s = self._mk_span(name)
                return s
        """}, rules=["span-context"])
    assert res.clean


# ---------------------------------------------------------------------------
# rule 6: every Pallas kernel has a referenced ref.py oracle
# ---------------------------------------------------------------------------
_KERNEL = """
    from jax.experimental import pallas as pl

    def mykern(x):
        return pl.pallas_call(lambda r, o: None, out_shape=x)(x)
"""


def test_kernel_parity_missing_oracle_triggers(tmp_path):
    res = sweep(tmp_path, {"src/repro/kernels/mykern.py": _KERNEL},
                rules=["kernel-parity"])
    assert rule_ids(res) == ["kernel-parity"]
    assert "mykern_ref" in res.findings[0].message


def test_kernel_parity_unreferenced_oracle_triggers(tmp_path):
    res = sweep(tmp_path, {
        "src/repro/kernels/mykern.py": _KERNEL,
        "src/repro/kernels/ref.py": "def mykern_ref(x):\n    return x\n",
        "tests/test_other.py": "def test_unrelated():\n    pass\n",
    }, rules=["kernel-parity"])
    assert rule_ids(res) == ["kernel-parity"]
    assert "not referenced" in res.findings[0].message


def test_kernel_parity_clean_when_test_references_oracle(tmp_path):
    res = sweep(tmp_path, {
        "src/repro/kernels/mykern.py": _KERNEL,
        "src/repro/kernels/ref.py": "def mykern_ref(x):\n    return x\n",
        "tests/test_parity.py": """
            def test_mykern_parity():
                from repro.kernels.ref import mykern_ref
                assert mykern_ref(1) == 1
        """}, rules=["kernel-parity"])
    assert res.clean


def test_kernel_parity_skips_non_pallas_modules(tmp_path):
    res = sweep(tmp_path, {
        "src/repro/kernels/helpers.py": "def pad(x):\n    return x\n",
    }, rules=["kernel-parity"])
    assert res.clean


# ---------------------------------------------------------------------------
# rule 7: no host sync in ServeEngine.step phase bodies
# ---------------------------------------------------------------------------
def test_host_sync_triggers(tmp_path):
    res = sweep(tmp_path, {"src/repro/serving/engine.py": """
        import jax.numpy as jnp
        import numpy as np

        class ServeEngine:
            def step(self):
                tok = np.asarray(jnp.argmax(self.logits))
                self.logits.block_until_ready()
                return float(jnp.sum(self.logits))
    """}, rules=["host-sync"])
    assert rule_ids(res) == ["host-sync"] * 3


def test_host_sync_clean_outside_phase_methods(tmp_path):
    res = sweep(tmp_path, {"src/repro/serving/engine.py": """
        import numpy as np

        class ServeEngine:
            def pop_query_result(self, rid):
                return np.asarray(self.results[rid])

        class Harness:
            def step(self):
                return np.asarray(self.x)
    """}, rules=["host-sync"])
    assert res.clean


# ---------------------------------------------------------------------------
# suppression + baseline semantics
# ---------------------------------------------------------------------------
def test_inline_suppression_silences_only_named_rule(tmp_path):
    res = sweep(tmp_path, {"src/repro/core/store.py": """
        import os

        def commit(tmp, final):
            os.replace(tmp, final)  # memlint: ignore[rename-fsync]
    """}, rules=["rename-fsync"])
    assert res.clean and len(res.suppressed) == 1

    # the wrong rule id suppresses nothing
    res = sweep(tmp_path, {"src/repro/core/store2.py": """
        import os

        def commit(tmp, final):
            os.replace(tmp, final)  # memlint: ignore[topk-tiebreak]
    """}, rules=["rename-fsync"])
    assert rule_ids(res) == ["rename-fsync"]


def test_standalone_suppression_comment_covers_next_line(tmp_path):
    res = sweep(tmp_path, {"src/repro/core/store.py": """
        import os

        def commit(tmp, final):
            # justified: tmp dir is recreated from scratch on recovery
            # memlint: ignore[rename-fsync]
            os.replace(tmp, final)
    """}, rules=["rename-fsync"])
    assert res.clean and len(res.suppressed) == 1


def test_wildcard_suppression(tmp_path):
    res = sweep(tmp_path, {"src/repro/core/store.py": """
        import os

        def commit(tmp, final):
            os.replace(tmp, final)  # memlint: ignore[*]
    """}, rules=["rename-fsync"])
    assert res.clean and len(res.suppressed) == 1


def test_baseline_tolerates_and_reports_stale(tmp_path):
    files = {"src/repro/core/store.py": """
        import os

        def commit(tmp, final):
            os.replace(tmp, final)
    """}
    first = sweep(tmp_path, files, rules=["rename-fsync"])
    assert len(first.findings) == 1
    key = first.findings[0].key

    res = sweep(tmp_path, files, rules=["rename-fsync"],
                baseline={key, "rename-fsync:src/gone.py:1"})
    assert res.clean and len(res.baselined) == 1
    assert res.stale_baseline == ["rename-fsync:src/gone.py:1"]


def test_syntax_error_is_a_finding(tmp_path):
    res = sweep(tmp_path, {"src/repro/core/broken.py": "def f(:\n"})
    assert rule_ids(res) == ["parse-error"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def _write_violation(tmp_path):
    p = tmp_path / "src" / "repro" / "core" / "store.py"
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text("import os\n\n"
                 "def commit(a, b):\n"
                 "    os.replace(a, b)\n")
    (tmp_path / "tests").mkdir(exist_ok=True)   # makes tmp_path the repo root
    return p


def test_cli_exit_codes_and_baseline_roundtrip(tmp_path, capsys):
    _write_violation(tmp_path)
    src = str(tmp_path / "src")

    assert memlint_main([src]) == 0                    # report-only mode
    assert memlint_main([src, "--strict"]) == 1        # strict gates
    out = capsys.readouterr().out
    assert "[rename-fsync]" in out and "1 finding(s)" in out

    base = str(tmp_path / "memlint_baseline.json")
    assert memlint_main([src, "--write-baseline", "--baseline", base]) == 0
    with open(base) as f:
        doc = json.load(f)
    assert doc["version"] == 1 and len(doc["findings"]) == 1
    # baselined finding no longer fails strict mode
    assert memlint_main([src, "--strict", "--baseline", base]) == 0


def test_cli_list_rules_and_rule_filter(tmp_path, capsys):
    _write_violation(tmp_path)
    assert memlint_main(["--list-rules"]) == 0
    assert "rename-fsync" in capsys.readouterr().out
    # filtering to an unrelated rule: the violation is invisible
    assert memlint_main([str(tmp_path / "src"), "--strict",
                         "--rules", "topk-tiebreak"]) == 0


# ---------------------------------------------------------------------------
# the real tree is clean — with an EMPTY committed baseline
# ---------------------------------------------------------------------------
def test_repo_sweeps_clean_with_empty_baseline():
    res = run_paths([os.path.join(REPO, "src")], repo_root=REPO)
    assert res.clean, "\n".join(f.render() for f in res.findings)
    assert res.files_swept > 50

    with open(os.path.join(REPO, "memlint_baseline.json")) as fh:
        base = json.load(fh)
    assert base["findings"] == [], "the committed baseline must stay empty"

    # every inline suppression in the tree carries a justification comment
    # (the suppressing line or the line above it says WHY, not just ignore)
    for f in res.suppressed:
        with open(os.path.join(REPO, f.path)) as fh:
            src = fh.read().splitlines()
        window = " ".join(src[max(0, f.line - 3): f.line])
        stripped = window.replace(f"memlint: ignore[{f.rule}]", "")
        assert len([w for w in stripped.split() if w.isalpha()]) >= 3, \
            f"suppression without justification at {f.path}:{f.line}"
