"""Observability subsystem (ISSUE 9): histogram quantile accuracy, span
nesting/timing, the no-op backend's cost, trace sink round-trips, and
metric coherence between the legacy ``metrics()`` dicts and the registry
under real mixed engine traffic."""
import json
import random
import time

import pytest

from repro import obs
from repro.obs import (JsonlSink, LatencyHistogram, MemorySink,
                       MetricsRegistry, Observability, percentiles,
                       read_trace)


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with the global tracer disabled."""
    obs.disable_tracing()
    yield
    obs.disable_tracing()


# ---------------------------------------------------------------------------
# histogram accuracy
# ---------------------------------------------------------------------------
def test_histogram_quantiles_match_exact_sort_within_bucket_error():
    """Reported quantiles stay within the documented relative error
    (GROWTH**0.5 - 1 per half-bucket, doubled for rank-vs-interpolation
    slack) of an exact sort across several orders of magnitude."""
    rng = random.Random(17)
    h = LatencyHistogram()
    samples = []
    for _ in range(20000):
        # log-uniform over ~1µs..1s — spans many buckets
        s = 10 ** rng.uniform(-6, 0)
        samples.append(s)
        h.record(s)
    exact = percentiles(samples, (0.50, 0.90, 0.99))
    rel_tol = 2 * (LatencyHistogram.GROWTH ** 0.5 - 1)     # ≈5%
    for q, key in ((0.50, "p50"), (0.90, "p90"), (0.99, "p99")):
        got = h.quantile(q)
        want = exact[key]
        assert abs(got - want) / want <= rel_tol, \
            f"q={q}: histogram {got:.3e} vs exact {want:.3e}"
    assert h.count == len(samples)
    assert h.max == max(samples)
    assert abs(h.sum - sum(samples)) < 1e-6


def test_histogram_edge_cases():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0                  # empty
    h.record(0.0)                                  # below MIN -> bucket 0
    assert h.quantile(0.5) == LatencyHistogram.MIN / 2
    h2 = LatencyHistogram()
    h2.record(1e9)                                 # beyond top bucket: clamped
    assert h2.quantile(0.99) > 0
    s = h2.summary()
    assert s["count"] == 1 and s["max_s"] == 1e9


def test_registry_get_or_create_and_snapshot():
    reg = MetricsRegistry()
    c = reg.counter("serve/x")
    assert reg.counter("serve/x") is c             # stable identity
    c.inc()
    c.inc(2)
    reg.gauge("serve/g").set(7)
    reg.histogram("span/phase").record(0.01)
    snap = reg.snapshot()
    assert snap["serve/x"] == 3
    assert snap["serve/g"] == 7
    assert snap["span/phase/count"] == 1
    assert "phase" in reg.latency_summary()


# ---------------------------------------------------------------------------
# spans: nesting, timing, sinks
# ---------------------------------------------------------------------------
def test_nested_span_timing_and_parenting():
    sink = MemorySink()
    obs.enable_tracing(sink)
    o = Observability()
    with o.span("outer", job="t") as outer:
        time.sleep(0.02)
        with o.span("inner") as inner:
            time.sleep(0.01)
            inner.event("marker", k=1)
    obs.disable_tracing()

    spans = {r["name"]: r for r in sink.spans()}
    assert set(spans) == {"outer", "inner"}
    # child closed first, parented to outer, strictly contained in time
    assert spans["inner"]["parent"] == spans["outer"]["span"]
    assert spans["outer"]["parent"] is None
    assert spans["inner"]["dur_s"] >= 0.01
    assert spans["outer"]["dur_s"] >= spans["inner"]["dur_s"] + 0.02 - 0.005
    assert spans["inner"]["ts"] >= spans["outer"]["ts"]
    assert spans["outer"]["attrs"] == {"job": "t"}
    # the event landed inside the inner span
    (ev,) = sink.events("marker")
    assert ev["span"] == spans["inner"]["span"]
    # span durations also recorded as registry histograms
    assert o.registry.histogram("span/outer").count == 1
    assert o.registry.histogram("span/inner").count == 1


def test_jsonl_sink_round_trip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    sink = JsonlSink(path)
    obs.enable_tracing(sink)
    o = Observability()
    with o.span("a", n=1):
        o.event("ping")
    obs.disable_tracing()
    sink.close()

    recs = read_trace(path)
    assert [r["kind"] for r in recs] == ["event", "span"]
    assert [r["name"] for r in recs] == ["ping", "a"]  # span written at close
    assert recs[1]["attrs"] == {"n": 1}
    # every line is valid standalone JSON
    with open(path) as f:
        for line in f:
            json.loads(line)


def test_noop_backend_is_shared_and_cheap():
    o = Observability()
    s1 = o.span("hot")
    s2 = o.span("hot2", attr=1)
    assert s1 is s2 is obs.NULL_SPAN           # no allocation while disabled
    with s1 as s:
        s.set(x=1).event("y")                  # all no-ops

    iters = 50_000
    t0 = time.perf_counter()
    for _ in range(iters):
        with o.span("hot"):
            pass
    per_call = (time.perf_counter() - t0) / iters
    assert per_call < 5e-6, f"disabled span cost {per_call * 1e6:.2f}µs"


def test_disabled_tracer_emits_nothing():
    sink = MemorySink()
    o = Observability()
    with o.span("quiet"):
        o.event("nope")
    assert sink.records == []
    assert o.registry.latency_summary() == {}  # no span histograms recorded


# ---------------------------------------------------------------------------
# metric coherence under mixed engine traffic
# ---------------------------------------------------------------------------
def test_engine_metrics_cohere_with_registry_under_mixed_traffic():
    """The legacy metrics() dict and the raw registry can never disagree —
    they are the same counters — and a traced engine run populates the
    per-phase span histograms for every active phase."""
    import jax
    import numpy as np

    from repro.config import MemForestConfig
    from repro.configs import get_smoke_config
    from repro.core.maintenance_plane import MaintenancePlane
    from repro.core.memforest import MemForestSystem
    from repro.data.synthetic import make_workload
    from repro.models import get_model
    from repro.serving.engine import ServeEngine

    wl = make_workload(num_entities=4, num_sessions=6,
                       transitions_per_entity=3, num_queries=8, seed=31)
    mf = MemForestSystem(MemForestConfig())
    plane = MaintenancePlane(mf.forest, flush_trees_per_unit=2)
    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=2, max_len=32, memory=mf,
                      maintenance=plane, maintenance_budget=2)

    sink = MemorySink()
    obs.enable_tracing(sink)
    rng = np.random.default_rng(3)
    for s in wl.sessions:
        eng.submit_session(s)
    eng.submit(list(rng.integers(3, 400, size=4)), max_new_tokens=3)
    eng.run_until_drained()        # maintenance lane retires deferred flushes
    rids = [eng.submit_query(q) for q in wl.queries]
    eng.run_until_drained()
    obs.disable_tracing()
    for r in rids:
        assert eng.pop_query_result(r) is not None

    m = eng.metrics()
    snap = eng.obs.registry.snapshot()
    pairs = [
        ("decode_steps", "serve/decode_steps"),
        ("decoded_tokens", "serve/decoded_tokens"),
        ("prefills", "serve/prefills"),
        ("ingest_batches", "serve/ingest_batches"),
        ("ingest_sessions", "serve/ingest_sessions"),
        ("query_batches", "serve/query_batches"),
        ("queries_served", "serve/queries_served"),
        ("maintenance_turns", "serve/maintenance_turns"),
    ]
    for legacy, reg_name in pairs:
        assert m[legacy] == snap[reg_name], (legacy, reg_name)
    # attribute back-compat reads the same counters
    assert eng.ingest_sessions == m["ingest_sessions"] == len(wl.sessions)
    assert eng.queries_served == len(wl.queries)
    # plane counters flow into the same dict from its own registry
    assert m["maintenance_units"] == plane.units_run
    assert m["maintenance_pending"] == 0
    # wait histograms saw every request
    assert snap["serve/ingest_wait_s/count"] == len(wl.sessions)
    assert snap["serve/query_wait_s/count"] == len(wl.queries)
    assert m["query_wait_p99_s"] >= m["query_wait_p50_s"] >= 0

    # the traced run populated per-phase histograms + the trace itself
    phases = eng.latency_summary()
    for want in ("engine.step", "engine.admit", "engine.decode",
                 "engine.drain.ingest", "engine.drain.query",
                 "engine.drain.maintenance"):
        assert want in phases and phases[want]["count"] > 0, want
    # the plane's own spans land in ITS registry (flush slices ran)
    assert "maintenance.flush_slice" in plane.obs.registry.latency_summary()
    step_spans = sink.spans("engine.step")
    assert len(step_spans) >= snap["serve/decode_steps"]  # idle steps traced too
    # drains nest under engine.step in the trace
    step_ids = {r["span"] for r in step_spans}
    for r in sink.spans("engine.drain.ingest"):
        assert r["parent"] in step_ids


def test_forest_flush_and_journal_spans_share_system_registry(tmp_path):
    """Forest flush + journal append/checkpoint spans land in the owning
    system's registry, and the JSONL trace nests fsync under append."""
    from repro.core.journal import DurableMemForest
    from repro.data.synthetic import make_workload

    sink = MemorySink()
    obs.enable_tracing(sink)
    store = DurableMemForest.open(str(tmp_path / "d"))
    wl = make_workload(num_entities=3, num_sessions=4,
                       transitions_per_entity=2, num_queries=2, seed=9)
    store.ingest_batch(wl.sessions, idempotency_key="k1")
    store.checkpoint()
    obs.disable_tracing()

    reg = store.obs.registry
    assert store.forest.obs is store.obs       # one registry per system
    summ = reg.latency_summary()
    for want in ("journal.append", "journal.fsync", "journal.checkpoint",
                 "forest.flush"):
        assert want in summ, want
    assert reg.counter("journal/appends").value == store.writer.appends
    assert reg.counter("journal/commits").value == store.ops_applied
    assert reg.counter("journal/checkpoints").value == 1
    append_ids = {r["span"] for r in sink.spans("journal.append")}
    for r in sink.spans("journal.fsync"):
        assert r["parent"] in append_ids
    store.close()


# ---------------------------------------------------------------------------
# thread-safety under the background maintenance plane (ISSUE 10 satellite):
# counters/histograms are written from the serve thread AND the plane's
# worker at once, and snapshots race lazy registration
# ---------------------------------------------------------------------------
def test_counter_and_histogram_are_thread_safe_under_contention():
    """`value += n` is a read-modify-write the GIL does not make atomic;
    with a tiny switch interval the unlocked version loses increments
    within a handful of runs. The locked primitives must count exactly."""
    import sys
    import threading

    reg = MetricsRegistry()
    c = reg.counter("stress/c")
    h = reg.histogram("stress/h")
    n_threads, n_iters = 8, 2000

    def worker(tid):
        for i in range(n_iters):
            c.inc()
            h.record(1e-4 * (1 + (i + tid) % 7))

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-6)
    try:
        ts = [threading.Thread(target=worker, args=(t,))
              for t in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    finally:
        sys.setswitchinterval(old)

    assert c.value == n_threads * n_iters
    assert h.count == n_threads * n_iters
    assert h.summary()["count"] == n_threads * n_iters
    # bucket totals agree with count: no torn record() left them skewed
    assert sum(h._b) == h.count


def test_registry_get_or_create_race_yields_one_instance():
    """Concurrent get-or-create of the SAME name from many threads must
    converge on one object — otherwise two components increment different
    counters under one name and the snapshot under-reports."""
    import sys
    import threading

    reg = MetricsRegistry()
    got = []

    def worker():
        for i in range(300):
            got.append((i, reg.counter(f"race/c{i}")))
            reg.histogram(f"race/h{i}")
            reg.gauge(f"race/g{i}")

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-6)
    try:
        ts = [threading.Thread(target=worker) for _ in range(6)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    finally:
        sys.setswitchinterval(old)

    by_name = {}
    for i, cnt in got:
        by_name.setdefault(i, set()).add(id(cnt))
    assert all(len(ids) == 1 for ids in by_name.values())


def test_snapshot_during_concurrent_registration_never_raises():
    """snapshot()/counters()/latency_summary() iterate the registry dicts
    while the maintenance worker is still registering new metrics lazily;
    unlocked iteration dies with 'dict changed size during iteration'."""
    import threading

    reg = MetricsRegistry()
    stop = threading.Event()
    errors = []

    def registrar():
        # fresh counter/gauge names keep the dicts growing (the iteration
        # race needs live insertions); histograms cycle over a small set so
        # snapshot()'s per-histogram summary cost stays bounded
        i = 0
        while not stop.is_set() and i < 20000:
            reg.counter(f"reg/c{i}").inc()
            reg.histogram(f"span/h{i % 32}").record(1e-3)
            reg.gauge(f"reg/g{i}").set(i)
            i += 1

    def snapshotter():
        try:
            for _ in range(150):
                reg.snapshot()
                reg.counters()
                reg.histograms()
                reg.latency_summary()
        except RuntimeError as e:          # pragma: no cover - the bug
            errors.append(e)

    reg_t = threading.Thread(target=registrar)
    snap_t = threading.Thread(target=snapshotter)
    reg_t.start()
    snap_t.start()
    snap_t.join()
    stop.set()
    reg_t.join()
    assert not errors


def test_tracer_event_races_disable_without_crashing():
    """Tracer.disable() nulls the sink from one thread while another is
    mid `_emit_event`; the emit path must capture the sink once (no
    check-then-act on self.sink)."""
    import threading

    from repro.obs.trace import Tracer

    errors = []

    def hammer(tr):
        try:
            for _ in range(300):
                tr.event("e", {"k": 1})
        except AttributeError as e:        # pragma: no cover - the bug
            errors.append(e)

    for _ in range(30):
        tr = Tracer()
        tr.enable(MemorySink())
        t = threading.Thread(target=hammer, args=(tr,))
        t.start()
        tr.disable()
        t.join()
    assert not errors
