"""End-to-end behaviour tests for the MemForest system (paper claims)."""
import numpy as np
import pytest

from repro.config import MemForestConfig
from repro.core.encoder import HashingEncoder
from repro.core.memforest import MemForestSystem
from repro.core.retrieval import answer_query
from repro.core.types import Query, Session, Turn
from repro.data.synthetic import make_workload


def _mk_system(**kw):
    return MemForestSystem(MemForestConfig(**kw))


@pytest.fixture(scope="module")
def workload():
    return make_workload(num_entities=6, num_sessions=10,
                         transitions_per_entity=3, num_queries=30, seed=3)


@pytest.fixture(scope="module")
def built_system(workload):
    mf = _mk_system()
    for s in workload.sessions:
        mf.ingest_session(s)
    return mf


def test_bob_residence_example():
    """The paper's §2.3.3 running example, verbatim: Boston -> Davis ->
    Miami; 'where before Miami?' must answer Davis, not Boston/Miami."""
    turns = [
        Turn("user", "Bob lives in Boston as of January 2023.", 36.0, 0),
        Turn("assistant", "Noted.", 36.0, 1),
        Turn("user", "Bob moved from Boston to Davis in May 2023.", 40.0, 2),
        Turn("assistant", "Got it.", 40.0, 3),
    ]
    s1 = Session("s1", turns)
    s2 = Session("s2", [
        Turn("user", "Bob moved from Davis to Miami in July 2024.", 54.0, 0),
        Turn("assistant", "Noted.", 54.0, 1),
    ])
    s3 = Session("s3", [
        Turn("user", "The weather has been quite nice lately.", 60.0, 0),
        Turn("assistant", "Indeed.", 60.0, 1),
    ])
    mf = _mk_system()
    for s in (s1, s2, s3):
        mf.ingest_session(s)

    q_cur = Query("Where does Bob live now?", "current", "Bob", "residence")
    assert mf.query(q_cur).answer == "Miami"

    q_hist = Query("Where did Bob live before moving to Miami?", "historical",
                   "Bob", "residence", anchor_value="Miami")
    assert mf.query(q_hist).answer == "Davis"

    q_when = Query("When did Bob move to Miami?", "transition_time",
                   "Bob", "residence", anchor_value="Miami")
    assert mf.query(q_when).answer == "July 2024"

    q_first = Query("What was the first place Bob lived in?", "multi_session",
                    "Bob", "residence")
    assert mf.query(q_first).answer == "Boston"


def test_ingestion_is_incremental(built_system, workload):
    """New sessions become queryable without global rewrites: dependency
    depth per session is extraction(1) + tree height, not O(state size)."""
    import math
    mf = built_system
    k = mf.config.branching_factor
    st = mf.ingest_session(workload.sessions[0])  # re-ingest: dedup path
    max_leaves = max(t.num_leaves for t in mf.forest.trees.values())
    bound = 1 + math.ceil(math.log(max(max_leaves, 2), max(2, (k + 1) // 2))) + 1
    assert st.llm_dependency_depth <= bound


def test_browse_mode_ordering(built_system, workload):
    """Paper Table 7 ordering: llm+planner >= llm > emb ~ flat > root-only
    (we assert the strong inequalities that the paper emphasizes)."""
    acc = {}
    for mode in ["flat", "root-only", "emb", "llm", "llm+planner"]:
        c = 0
        for q in workload.queries:
            r = built_system.query(q, mode=mode, final_topk=6)
            c += int(r.answer.strip().lower() == q.gold.strip().lower())
        acc[mode] = c
    assert acc["llm"] > acc["emb"], acc
    assert acc["llm+planner"] >= acc["llm"], acc
    assert acc["llm"] > acc["flat"], acc
    assert acc["llm+planner"] > acc["root-only"], acc


def test_memforest_beats_baselines(workload):
    from repro.core.baselines import ALL_BASELINES
    mf = _mk_system()
    for s in workload.sessions:
        mf.ingest_session(s)
    mf_acc = sum(
        int(mf.query(q, final_topk=6).answer.strip().lower() == q.gold.strip().lower())
        for q in workload.queries
    )
    for name, cls in ALL_BASELINES.items():
        sys_ = cls(HashingEncoder(dim=256))
        for s in workload.sessions:
            sys_.ingest_session(s)
        acc = sum(
            int(sys_.query(q, final_topk=6).answer.strip().lower() == q.gold.strip().lower())
            for q in workload.queries
        )
        assert mf_acc >= acc, (name, mf_acc, acc)


def test_mem0_loses_history(workload):
    """The paper's §2.3.2 failure mode: in-place updates destroy the history
    needed for first-value (multi-session) queries."""
    from repro.core.baselines import Mem0Like
    m0 = Mem0Like(HashingEncoder(dim=256))
    mf = _mk_system()
    for s in workload.sessions:
        m0.ingest_session(s)
        mf.ingest_session(s)
    multi = [q for q in workload.queries if q.qtype == "multi_session"]
    if not multi:
        pytest.skip("no multi-session queries in workload")
    m0_acc = sum(int(m0.query(q).answer.strip().lower() == q.gold.strip().lower()) for q in multi)
    mf_acc = sum(int(mf.query(q).answer.strip().lower() == q.gold.strip().lower()) for q in multi)
    assert mf_acc > m0_acc


def test_parallel_extraction_depth_vs_sequential(workload):
    par = MemForestSystem(MemForestConfig(), parallel_extraction=True)
    seq = MemForestSystem(MemForestConfig(), parallel_extraction=False)
    s = workload.sessions[0]
    st_p = par.ingest_session(s)
    st_s = seq.ingest_session(s)
    assert st_p.llm_dependency_depth < st_s.llm_dependency_depth
    # identical persistent state
    assert par.scale_stats()["facts"] == seq.scale_stats()["facts"]


def test_write_path_scales_with_new_evidence_not_state(workload):
    """Paper's central write claim: cost of ingesting session k is flat in k
    (refreshes ~ per-session evidence), unlike O(N) profile systems."""
    mf = _mk_system()
    refreshes = []
    for s in workload.sessions:
        before = mf.forest.summary_refreshes
        mf.ingest_session(s)
        refreshes.append(mf.forest.summary_refreshes - before)
    # late-session refresh cost must not grow linearly with accumulated state
    early = np.mean(refreshes[:3])
    late = np.mean(refreshes[-3:])
    assert late < early * 3, refreshes


def test_shared_answerer_semantics():
    from repro.core.types import CanonicalFact
    facts = [
        CanonicalFact(0, "", "Bob", "residence", "Boston", 1.0),
        CanonicalFact(1, "", "Bob", "residence", "Davis", 5.0, prev_value="Boston"),
        CanonicalFact(2, "", "Bob", "residence", "Miami", 9.0, prev_value="Davis"),
    ]
    assert answer_query(Query("", "current", "Bob", "residence"), facts) == "Miami"
    assert answer_query(Query("", "historical", "Bob", "residence",
                              anchor_value="Miami"), facts) == "Davis"
    assert answer_query(Query("", "multi_session", "Bob", "residence"), facts) == "Boston"
    assert answer_query(Query("", "current", "Alice", "residence"), facts) == ""
