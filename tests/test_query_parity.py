"""Batched read path parity: the level-synchronous batched browse must
return IDENTICAL facts and evidence to the single-query path for every
browse mode, and the device-resident index caches must stay coherent across
flush/ingest/delete (invalidation correctness)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:           # in-repo fallback (tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.config import MemForestConfig
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload

MODES = ["flat", "root-only", "emb", "emb+planner", "llm", "llm+planner"]


def _fact_sig(facts):
    return [(f.fact_id, f.text, f.value) for f in facts]


@pytest.fixture(scope="module")
def built():
    wl = make_workload(num_entities=6, num_sessions=10,
                       transitions_per_entity=4, num_queries=30, seed=7)
    mf = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        mf.ingest_session(s)
    return mf, wl


# ---------------------------------------------------------------------------
# per-mode parity: batched == scalar, exactly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", MODES)
def test_batched_browse_identical_to_scalar(built, mode):
    mf, wl = built
    texts = [q.text for q in wl.queries]
    singles = [mf.retriever.retrieve(t, mode=mode) for t in texts]
    batched = mf.retriever.retrieve_batch(texts, mode=mode)
    for (f1, e1, _), (f2, e2, _) in zip(singles, batched):
        assert _fact_sig(f1) == _fact_sig(f2)
        assert e1 == e2


def test_query_batch_identical_answers(built):
    mf, wl = built
    singles = [mf.query(q).answer for q in wl.queries]
    batched = [r.answer for r in mf.query_batch(wl.queries)]
    assert singles == batched


def test_batch_size_invariance(built):
    """Packing must not leak state across lanes: any chunking of the same
    query stream yields the same results."""
    mf, wl = built
    texts = [q.text for q in wl.queries]
    whole = mf.retriever.retrieve_batch(texts, mode="llm+planner")
    chunked = []
    for i in range(0, len(texts), 7):
        chunked.extend(mf.retriever.retrieve_batch(texts[i:i + 7],
                                                   mode="llm+planner"))
    for (f1, e1, _), (f2, e2, _) in zip(whole, chunked):
        assert _fact_sig(f1) == _fact_sig(f2)
        assert e1 == e2


def test_batched_browse_launch_count(built):
    """The point of level-synchronous packing: browse kernel launches scale
    with tree depth, not with batch size."""
    mf, wl = built
    texts = [q.text for q in wl.queries]
    r = mf.retriever
    c0 = r.browse_launches
    r.retrieve_batch(texts, mode="llm")
    batched_launches = r.browse_launches - c0
    c0 = r.browse_launches
    for t in texts:
        r.retrieve(t, mode="llm")
    scalar_launches = r.browse_launches - c0
    assert batched_launches * 4 <= scalar_launches, (
        batched_launches, scalar_launches)


# ---------------------------------------------------------------------------
# property check: parity over random forests
# ---------------------------------------------------------------------------
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_parity_propcheck(seed):
    rng = np.random.default_rng(seed)
    wl = make_workload(num_entities=int(rng.integers(2, 6)),
                       num_sessions=int(rng.integers(2, 8)),
                       transitions_per_entity=int(rng.integers(2, 5)),
                       num_queries=8, seed=seed % 9973)
    mf = MemForestSystem(MemForestConfig(
        branching_factor=int(rng.integers(3, 10))))
    for s in wl.sessions:
        mf.ingest_session(s)
    texts = [q.text for q in wl.queries]
    mode = ["emb", "llm", "llm+planner"][seed % 3]
    singles = [mf.retriever.retrieve(t, mode=mode) for t in texts]
    batched = mf.retriever.retrieve_batch(texts, mode=mode)
    for (f1, e1, _), (f2, e2, _) in zip(singles, batched):
        assert _fact_sig(f1) == _fact_sig(f2)
        assert e1 == e2


# ---------------------------------------------------------------------------
# device-index invalidation correctness
# ---------------------------------------------------------------------------
def _all_results(mf, queries, mode="llm+planner"):
    return [(_fact_sig(r[0]), r[1])
            for r in mf.retriever.retrieve_batch([q.text for q in queries],
                                                 mode=mode)]


def test_results_unchanged_across_flush():
    """A flush with no intervening writes must not change query results
    (re-uploading/incrementally updating the device cache is a no-op)."""
    wl = make_workload(num_entities=4, num_sessions=8,
                       transitions_per_entity=3, num_queries=12, seed=11)
    mf = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        mf.ingest_session(s)
    before = _all_results(mf, wl.queries)
    mf.forest.flush()
    after = _all_results(mf, wl.queries)
    assert before == after


def test_index_cache_invalidation_on_ingest():
    """Incremental ingestion + cached device indexes must equal a fresh
    system that ingested everything (no stale rows, no missed appends)."""
    wl = make_workload(num_entities=5, num_sessions=10,
                       transitions_per_entity=3, num_queries=15, seed=13)
    half = len(wl.sessions) // 2

    inc = MemForestSystem(MemForestConfig())
    for s in wl.sessions[:half]:
        inc.ingest_session(s)
    _all_results(inc, wl.queries)      # populate the device caches
    assert inc.forest.index_uploads > 0
    for s in wl.sessions[half:]:
        inc.ingest_session(s)

    fresh = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        fresh.ingest_session(s)

    assert _all_results(inc, wl.queries) == _all_results(fresh, wl.queries)


def test_index_cache_invalidation_on_delete():
    """delete_session edits fact rows in place — the device cache must drop
    the dead rows (kill_fact scatter invalidation)."""
    wl = make_workload(num_entities=4, num_sessions=8,
                       transitions_per_entity=3, num_queries=12, seed=17)
    mf = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        mf.ingest_session(s)
    _all_results(mf, wl.queries)       # populate the device caches
    sid = wl.sessions[0].session_id
    mf.delete_session(sid)
    after = _all_results(mf, wl.queries)
    # no retrieved fact may reference the deleted-and-unsupported rows
    for sig, _ev in after:
        for fid, _text, _val in sig:
            if fid >= 0:
                assert mf.forest.fact_alive[fid]
    # and the results must match a scalar re-query (cache == host truth)
    singles = [(_fact_sig(f), e) for f, e, _ in
               (mf.retriever.retrieve(q.text, mode="llm+planner")
                for q in wl.queries)]
    assert after == singles
