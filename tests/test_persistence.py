"""Memory-substrate durability: save/load roundtrip + derived-artifact
rematerialization from persistent state (paper §4.4 migration)."""
import numpy as np
import pytest

from repro.config import MemForestConfig
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload


@pytest.fixture(scope="module")
def built():
    wl = make_workload(num_entities=5, num_sessions=8,
                       transitions_per_entity=3, num_queries=20, seed=9)
    mf = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        mf.ingest_session(s)
    return mf, wl


def _answers(mf, wl, mode="llm+planner"):
    return [mf.query(q, mode=mode).answer for q in wl.queries]


def test_roundtrip_with_derived(built, tmp_path):
    mf, wl = built
    p = str(tmp_path / "memory.mfz")
    mf.save(p)
    mf2 = MemForestSystem.load(p)
    assert mf2.scale_stats() == mf.scale_stats()
    assert _answers(mf2, wl) == _answers(mf, wl)
    for t in mf2.forest.trees.values():
        t.check_invariants()


def test_rematerialize_derived_from_persistent_state(built, tmp_path):
    """Drop every derived artifact (summaries, node embs, root rows) and
    regenerate from canonical facts + structure — answers must match."""
    mf, wl = built
    p = str(tmp_path / "memory_thin.mfz")
    mf.save(p, with_derived=False)
    mf2 = MemForestSystem.load(p, rematerialize_derived=True)
    assert mf2.scale_stats() == mf.scale_stats()
    a1, a2 = _answers(mf, wl), _answers(mf2, wl)
    same = sum(int(x == y) for x, y in zip(a1, a2))
    assert same >= len(a1) * 0.9, (same, len(a1))
    # internal summaries actually regenerated (non-zero, unit norm)
    t = next(iter(mf2.forest.trees.values()))
    for nid in range(t._n):
        if t.alive[nid] and t.level[nid] > 0:
            assert abs(np.linalg.norm(t.emb[nid]) - 1.0) < 1e-3


def test_load_then_continue_ingesting(built, tmp_path):
    mf, wl = built
    p = str(tmp_path / "memory2.mfz")
    mf.save(p)
    mf2 = MemForestSystem.load(p)
    extra = make_workload(num_entities=3, num_sessions=2, num_queries=1,
                          seed=123)
    before = mf2.scale_stats()["facts"]
    for s in extra.sessions:
        mf2.ingest_session(s)
    assert mf2.scale_stats()["facts"] > before
    for t in mf2.forest.trees.values():
        t.check_invariants()

def test_deleted_facts_stay_dead_after_save_load(tmp_path):
    """Regression: save -> delete -> save -> load must NOT resurrect deleted
    facts. load_forest used to repopulate fact_emb rows from the persisted
    fact records regardless of fact_alive, so tombstoned facts scored again
    in topk_sim after a restore."""
    wl = make_workload(num_entities=5, num_sessions=8,
                       transitions_per_entity=3, num_queries=20, seed=13)
    mf = MemForestSystem(MemForestConfig())
    mf.ingest_batch(wl.sessions)
    mf.save(str(tmp_path / "pre_delete.mfz"))

    dead = []
    for s in wl.sessions:
        mf.delete_session(s.session_id)
        dead = [f.fact_id for f in mf.forest.facts
                if not mf.forest.fact_alive[f.fact_id]]
        if dead:
            break
    assert dead, "workload produced no fully-dead facts"
    want = [r.answer for r in mf.query_batch(wl.queries)]

    p = str(tmp_path / "post_delete.mfz")
    mf.save(p)
    mf2 = MemForestSystem.load(p)

    # host index rows stay zeroed...
    for fid in dead:
        assert not mf2.forest.fact_alive[fid]
        assert np.linalg.norm(mf2.forest.fact_emb[fid]) == 0.0
        # ...but provenance is kept for the record
        assert mf2.forest.facts[fid].emb is not None
    # ...and so does the device-resident index the batched read path scores
    dev, n = mf2.forest.fact_index_device()
    devnp = np.asarray(dev)
    for fid in dead:
        assert float(np.abs(devnp[fid]).max()) == 0.0

    # dead facts never surface through retrieval, single or batched
    for q in wl.queries:
        facts, _evidence, _stats = mf2.retriever.retrieve(q.text)
        assert all(mf2.forest.fact_alive[f.fact_id] for f in facts)
    assert [r.answer for r in mf2.query_batch(wl.queries)] == want
