"""Durable write path (core/journal.py) + async maintenance plane
(core/maintenance_plane.py): WAL framing, exactly-once idempotency keys,
crash-point sweep over every durability boundary, snapshot + journal-tail
recovery, deferred-flush equivalence, tombstone compaction."""
import os
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:           # in-repo fallback (tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.config import MemForestConfig
from repro.core import maintenance, persistence
from repro.core.journal import (JOURNAL_NAME, DurableMemForest, JournalWriter,
                                read_journal)
from repro.core.maintenance_plane import MaintenancePlane
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload
from repro.runtime.fault_tolerance import CrashInjector, SimulatedCrash


@pytest.fixture(scope="module")
def wl():
    return make_workload(num_entities=4, num_sessions=6,
                         transitions_per_entity=2, num_queries=8, seed=11)


@pytest.fixture(scope="module")
def merge_wl():
    return make_workload(num_entities=3, num_sessions=2,
                         transitions_per_entity=2, num_queries=2, seed=12)


def _build(sessions):
    mf = MemForestSystem(MemForestConfig())
    mf.ingest_batch(list(sessions))
    return mf


def _plan(wl, merge_wl):
    """The op mix every recovery test replays: batched ingests, a targeted
    deletion, and a migration merge — each with a stable client key so
    retries after a simulated crash dedup instead of double-applying."""
    return [
        ("ingest", "client:i0", wl.sessions[:2]),
        ("ingest", "client:i1", wl.sessions[2:4]),
        ("delete", "client:d0", wl.sessions[0].session_id),
        ("merge", "client:m0", merge_wl.sessions),
        ("ingest", "client:i2", wl.sessions[4:]),
    ]


def _apply(store, op):
    kind, key, arg = op
    if kind == "ingest":
        store.ingest_batch(arg, idempotency_key=key)
    elif kind == "delete":
        store.delete_session(arg, idempotency_key=key)
    elif kind == "compact":
        # deterministic scope selection + per-scope client keys: retried
        # after a crash, already-compacted trees drop out of the candidate
        # set (dead fraction 0) or dedup on their key
        for scope in sorted(maintenance.compaction_candidates(
                store.forest, min_dead_fraction=0.01)):
            store.compact_tree(scope, idempotency_key=f"{key}:{scope}")
    elif kind == "demote":
        # checkpoint-class (snapshot + journal rotation + device free): no
        # journal record, no key — a retry after a crash just demotes again
        store.demote()
    else:
        store.merge_from(_build(arg), idempotency_key=key)


def _run_uninterrupted(root, ops, **kw):
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root, **kw)
    for op in ops:
        _apply(store, op)
    store.close()
    return store


# ---------------------------------------------------------------------------
# journal framing
# ---------------------------------------------------------------------------
def test_journal_frame_roundtrip(tmp_path):
    p = str(tmp_path / "j.waj")
    w = JournalWriter(p)
    recs = [{"seq": i, "op": "ingest_batch", "key": f"k{i}",
             "payload": {"x": [i] * i}} for i in range(1, 4)]
    for r in recs:
        w.append(r)
    w.close()
    assert read_journal(p) == recs


def test_journal_torn_tail_ends_replay_cleanly(tmp_path):
    def fresh(name):
        p = str(tmp_path / name)
        w = JournalWriter(p)
        for i in range(3):
            w.append({"seq": i + 1, "op": "delete_session", "key": f"k{i}",
                      "payload": {"session_id": "s" * 40}})
        w.close()
        return p

    # crash mid-append: the last frame is truncated
    p = fresh("trunc.waj")
    with open(p, "rb+") as f:
        f.truncate(os.path.getsize(p) - 7)
    assert [r["seq"] for r in read_journal(p)] == [1, 2]

    # crash left a corrupt (CRC-failing) tail instead of a short one
    p = fresh("crc.waj")
    with open(p, "rb+") as f:
        f.seek(-1, os.SEEK_END)
        f.write(b"\xff")
    assert [r["seq"] for r in read_journal(p)] == [1, 2]

    # a tail header promising more bytes than exist is also torn — the
    # complete prefix still replays
    p = fresh("short.waj")
    with open(p, "ab") as f:
        f.write(b"\xff\xff\xff\x7f garbage")
    assert [r["seq"] for r in read_journal(p)] == [1, 2, 3]


def test_missing_journal_reads_empty(tmp_path):
    assert read_journal(str(tmp_path / "nope.waj")) == []


def test_recovery_truncates_torn_tail_before_appending(tmp_path, wl):
    """A torn tail frame must be cut on open(): appends landing AFTER the
    garbage would be fsync-acked yet dropped by every later scan."""
    root = str(tmp_path / "store")
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root)
    store.ingest_batch(wl.sessions[:2], idempotency_key="i0")
    store.close()
    jpath = os.path.join(root, JOURNAL_NAME)
    with open(jpath, "ab") as f:                # crash mid-append
        f.write(b"\xde\xad\xbe\xef torn frame garbage")
    torn_size = os.path.getsize(jpath)

    rec = DurableMemForest.open(root)
    assert os.path.getsize(jpath) < torn_size   # tail truncated, not kept
    rec.ingest_batch(wl.sessions[2:4], idempotency_key="i1")
    want = rec.state_digest()
    rec.close()

    rec2 = DurableMemForest.open(root)          # i1 must survive THIS recovery
    assert rec2.ops_replayed == 2
    assert rec2.state_digest() == want
    rec2.close()


# ---------------------------------------------------------------------------
# exactly-once idempotency
# ---------------------------------------------------------------------------
def test_duplicate_delivery_applies_exactly_once(tmp_path, wl):
    root = str(tmp_path / "store")
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root)
    assert store.ingest_batch(wl.sessions[:2], idempotency_key="hook:1") is not None
    d0 = store.state_digest()
    n0 = store.scale_stats()

    # duplicated webhook delivery: same key, must be a no-op end to end
    assert store.ingest_batch(wl.sessions[:2], idempotency_key="hook:1") is None
    assert store.duplicates_skipped == 1
    assert store.state_digest() == d0
    assert store.scale_stats() == n0
    # the duplicate never reached the journal
    assert len(read_journal(os.path.join(root, JOURNAL_NAME))) == 1
    store.close()


def test_journaled_merge_idempotent_under_key(tmp_path, wl, merge_wl):
    root = str(tmp_path / "store")
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root)
    store.ingest_batch(wl.sessions[:2], idempotency_key="i")
    src = _build(merge_wl.sessions)
    assert store.merge_from(src, idempotency_key="m") is not None
    d0 = store.state_digest()
    assert store.merge_from(src, idempotency_key="m") is None
    assert store.state_digest() == d0
    store.close()


def test_durable_path_matches_plain_system(tmp_path, wl, merge_wl):
    """Journaling is a shell: answers and scale are identical to running the
    same lifecycle directly on a MemForestSystem."""
    ops = _plan(wl, merge_wl)
    store = _run_uninterrupted(str(tmp_path / "store"), ops)

    plain = MemForestSystem(MemForestConfig())
    for kind, _key, arg in ops:
        if kind == "ingest":
            plain.ingest_batch(arg)
        elif kind == "delete":
            plain.delete_session(arg)
        else:
            plain.merge_from(_build(arg))

    assert store.scale_stats() == plain.scale_stats()
    got = [r.answer for r in store.query_batch(wl.queries)]
    want = [r.answer for r in plain.query_batch(wl.queries)]
    assert got == want


# ---------------------------------------------------------------------------
# recovery: snapshot + journal tail
# ---------------------------------------------------------------------------
def test_recovery_replays_snapshot_plus_tail(tmp_path, wl, merge_wl):
    ops = _plan(wl, merge_wl)
    root = str(tmp_path / "store")
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root)
    for op in ops[:2]:
        _apply(store, op)
    store.checkpoint()                      # snapshot covers the first two ops
    for op in ops[2:]:
        _apply(store, op)
    want_digest = store.state_digest()
    want_answers = [r.answer for r in store.query_batch(wl.queries)]
    store.close()                           # "crash" after the last append

    rec = DurableMemForest.open(root)
    assert rec.ops_replayed == len(ops) - 2  # tail only, not the snapshot ops
    assert rec.state_digest() == want_digest
    assert [r.answer for r in rec.query_batch(wl.queries)] == want_answers
    for t in rec.forest.trees.values():
        t.check_invariants()
    rec.close()


def test_recovery_is_pure_replay_without_snapshot(tmp_path, wl, merge_wl):
    """No checkpoint ever taken: open() rebuilds the whole state from the
    journal alone — including the merge, whose source forest rides inside
    its journal record and no longer exists at recovery time."""
    ops = _plan(wl, merge_wl)
    root = str(tmp_path / "store")
    store = _run_uninterrupted(root, ops)
    want = store.state_digest()
    del store                               # the source of truth is now disk

    rec = DurableMemForest.open(root)
    assert rec.ops_replayed == len(ops)
    assert rec.state_digest() == want
    rec.close()


def test_checkpoint_under_deferred_flush_recovers_fresh_summaries(tmp_path, wl):
    """A snapshot taken while flushes are deferred bakes in stale internal
    summaries; it must also carry the dirty marks, or the restored store
    reports clean derived state and read-triggered refresh never repairs
    the staleness."""
    ref = MemForestSystem(MemForestConfig())
    ref.ingest_batch(list(wl.sessions))          # inline flush
    want = [r.answer for r in ref.query_batch(wl.queries)]

    root = str(tmp_path / "store")
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root)
    store.ingest_batch(wl.sessions, idempotency_key="i", defer_flush=True)
    assert store.forest.dirty_trees              # snapshot lands mid-deferral
    store.checkpoint()
    store.close()

    rec = DurableMemForest.open(root)
    assert rec.ops_replayed == 0                 # the snapshot covers the op...
    assert rec.forest.dirty_trees                # ...and re-marks its debt
    assert any(t.dirty for t in rec.forest.trees.values())
    assert [r.answer for r in rec.query_batch(wl.queries)] == want
    assert not rec.forest.dirty_trees            # reader paid the flush
    rec.close()


def test_journaled_compaction_recovers_and_dedups(tmp_path, wl):
    root = str(tmp_path / "store")
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root)
    store.ingest_batch(wl.sessions, idempotency_key="i")
    for s in wl.sessions[:3]:
        store.delete_session(s.session_id, idempotency_key=f"d:{s.session_id}")
    scopes = sorted(maintenance.compaction_candidates(
        store.forest, min_dead_fraction=0.01))
    assert scopes
    for scope in scopes:
        assert store.compact_tree(scope, idempotency_key=f"c:{scope}") is not None
        # the journal-retry case: same key is a no-op
        assert store.compact_tree(scope, idempotency_key=f"c:{scope}") is None
    want = store.state_digest()
    n_records = len(read_journal(os.path.join(root, JOURNAL_NAME)))
    store.close()

    # compaction rewrote placement + arenas (persistent state) — pure replay
    # must land on the exact post-compaction digest
    rec = DurableMemForest.open(root)
    assert rec.ops_replayed == n_records
    assert rec.state_digest() == want
    for t in rec.forest.trees.values():
        t.check_invariants()
    rec.close()


def test_snapshot_gc_honors_small_keep_counts(tmp_path, wl):
    for keep in (0, 1):
        root = str(tmp_path / f"keep{keep}")
        store = DurableMemForest(MemForestSystem(MemForestConfig()), root,
                                 keep_snapshots=keep)
        for i in range(3):
            store.ingest_batch(wl.sessions[i:i + 1], idempotency_key=f"i{i}")
            store.checkpoint()
        snaps = [n for n in os.listdir(root) if n.startswith("snapshot_")]
        # keep=0 used to slice snaps[:-0] == [] and GC nothing; the
        # LATEST-pointed snapshot itself is always retained
        assert len(snaps) == max(keep, 1)
        store.close()


def test_reopen_is_stable_fixed_point(tmp_path, wl, merge_wl):
    """open(); close(); open() — recovery of a recovered store is a no-op
    state-wise (replay respects applied keys and the snapshot watermark)."""
    root = str(tmp_path / "store")
    want = _run_uninterrupted(root, _plan(wl, merge_wl),
                              snapshot_every=2).state_digest()
    a = DurableMemForest.open(root)
    da = a.state_digest()
    a.checkpoint()
    a.close()
    b = DurableMemForest.open(root)
    assert b.ops_replayed == 0
    assert da == want == b.state_digest()
    b.close()


# ---------------------------------------------------------------------------
# crash injection: every durability boundary
# ---------------------------------------------------------------------------
def _run_with_crash_then_recover(root, ops, crash_at, snapshot_every=2):
    """Client-side retry loop: on SimulatedCrash the in-memory store is
    discarded (process death), recovery reopens from disk, and the unacked
    op is retried under its original idempotency key."""
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root,
                             snapshot_every=snapshot_every,
                             crash=CrashInjector(crash_at))
    crashes = 0
    for op in ops:
        while True:
            try:
                _apply(store, op)
                break
            except SimulatedCrash:
                crashes += 1
                store.close()
                store = DurableMemForest.open(root,
                                              snapshot_every=snapshot_every)
    store.close()
    return store, crashes


def test_crash_sweep_every_durability_boundary(tmp_path, wl, merge_wl):
    ops = _plan(wl, merge_wl)
    want = _run_uninterrupted(str(tmp_path / "ref"), ops,
                              snapshot_every=2).state_digest()

    # size the sweep: a no-crash probe records the full event trace
    probe = CrashInjector(None)
    _run_uninterrupted(str(tmp_path / "probe"), ops, snapshot_every=2,
                       crash=probe)
    assert probe.events >= 3 * len(ops)     # submit/append/apply per op
    assert "snapshot:commit" in probe.trace and "journal:rotate" in probe.trace

    fired = 0
    for k in range(1, probe.events + 1):
        root = str(tmp_path / f"crash_{k:02d}")
        store, crashes = _run_with_crash_then_recover(root, ops, k)
        fired += crashes
        assert store.state_digest() == want, \
            f"state diverged after crash at event #{k} ({probe.trace[k - 1]})"
    assert fired == probe.events            # every kill point actually fired


def test_crash_sweep_journaled_compaction(tmp_path, wl, merge_wl):
    """Kill the process at every durability boundary in the compaction
    window: recovery must replay the journaled compact ops and reconverge
    on the post-compaction digest (compaction rewrites placement rows and
    arenas, which the digest counts as persistent state)."""
    base = _plan(wl, merge_wl)
    ops = base[:3] + [("compact", "client:c0", None)] + base[3:]
    want = _run_uninterrupted(str(tmp_path / "ref"), ops,
                              snapshot_every=2).state_digest()

    probe = CrashInjector(None)
    _run_uninterrupted(str(tmp_path / "probe"), ops, snapshot_every=2,
                       crash=probe)
    n_compacts = probe.trace.count("submit:compact_tree")
    assert n_compacts > 0                       # the compaction actually fired
    # sweep only the compaction window (its submit/append/apply ticks plus
    # any snapshot the auto-checkpoint interleaves) to bound runtime
    lo = probe.trace.index("submit:compact_tree")
    hi = min(lo + 3 * n_compacts + 4, probe.events)
    for k in range(lo + 1, hi + 1):
        root = str(tmp_path / f"crash_{k:02d}")
        store, _ = _run_with_crash_then_recover(root, ops, k)
        assert store.state_digest() == want, \
            f"state diverged after crash at event #{k} ({probe.trace[k - 1]})"


def test_crash_sweep_demotion_boundary(tmp_path, wl, merge_wl):
    """Kill the process at every durability boundary in the demotion window
    (residency eviction = snapshot + LATEST flip + journal rotation + device
    free): demotion changes NO persistent state, so recovery must land on
    the uninterrupted digest no matter where the kill hits — and the ops
    that follow the demotion must apply to the recovered store cleanly."""
    base = _plan(wl, merge_wl)
    ops = base[:3] + [("demote", None, None)] + base[3:]
    want = _run_uninterrupted(str(tmp_path / "ref"), ops,
                              snapshot_every=2).state_digest()

    probe = CrashInjector(None)
    _run_uninterrupted(str(tmp_path / "probe"), ops, snapshot_every=2,
                       crash=probe)
    assert "demote:begin" in probe.trace and "demote:commit" in probe.trace
    lo = probe.trace.index("demote:begin")
    hi = probe.trace.index("demote:commit") + 1
    for k in range(lo + 1, hi + 1):
        root = str(tmp_path / f"crash_{k:02d}")
        store, crashes = _run_with_crash_then_recover(root, ops, k)
        assert crashes >= 1                     # the kill point actually fired
        assert store.state_digest() == want, \
            f"state diverged after crash at event #{k} ({probe.trace[k - 1]})"


def test_crash_sweep_manager_demote_and_rehydrate(tmp_path, wl):
    """Residency-manager lifecycle under the same sweep: crash at every
    boundary of demote (digest write + checkpoint-class demotion) and of the
    cold-query rehydration; a restarted manager must recover digest- and
    answer-identical. Rehydration IS the crash-recovery open, so this also
    pins that equivalence."""
    from repro.core.residency import ResidencyConfig, ResidencyManager

    def build(root, crash=None):
        return ResidencyManager(
            root, config=ResidencyConfig(hot_budget=2, digest_threshold=-99.0),
            mem_config=MemForestConfig(), crash=crash)

    def lifecycle(mgr):
        mgr.ingest("t", wl.sessions[:4], idempotency_key="i0")
        mgr.demote("t")
        return [r.answer for r in mgr.query_batch("t", wl.queries)]

    ref = build(str(tmp_path / "ref"))
    want_ans = lifecycle(ref)                   # demote -> escalate -> rehydrate
    want_digest = ref.state_digest("t")
    ref.close()

    probe = CrashInjector(None)
    mgr = build(str(tmp_path / "probe"), crash=probe)
    mgr.ingest("t", wl.sessions[:4], idempotency_key="i0")
    events_ingest = probe.events                # covered by the core sweep
    mgr.demote("t")
    mgr.query_batch("t", wl.queries)
    mgr.close()
    for ev in ("demote:digest", "demote:begin", "demote:commit",
               "rehydrate:begin", "rehydrate:commit"):
        assert ev in probe.trace

    for k in range(events_ingest + 1, probe.events + 1):
        root = str(tmp_path / f"crash_{k:02d}")
        mgr = build(root, crash=CrashInjector(k))
        try:
            lifecycle(mgr)
            crashed = False
        except SimulatedCrash:                  # process death mid-transition
            crashed = True
        mgr.close()
        assert crashed, f"kill point #{k} never fired"
        rec = build(root)                       # fresh process over the dir
        assert rec.tenant_ids() == ["t"]
        assert [r.answer for r in rec.query_batch("t", wl.queries)] == want_ans
        assert rec.state_digest("t") == want_digest, \
            f"state diverged after crash at event #{k} ({probe.trace[k - 1]})"
        rec.close()


@settings(max_examples=4, deadline=None)
@given(crash_at=st.integers(min_value=1, max_value=60),
       rot=st.integers(min_value=0, max_value=4))
def test_prop_any_crash_prefix_recovers_state_identical(crash_at, rot):
    """Property: for ANY op ordering and ANY kill point, snapshot + journal
    tail + client retry reconverges to the uninterrupted run's digest. A
    crash_at beyond the trace simply never fires — the uninterrupted case."""
    wl = make_workload(num_entities=3, num_sessions=4,
                       transitions_per_entity=2, num_queries=2,
                       seed=100 + rot)
    mwl = make_workload(num_entities=2, num_sessions=2,
                        transitions_per_entity=2, num_queries=1,
                        seed=200 + rot)
    ops = _plan(wl, mwl)
    ops = ops[rot:] + ops[:rot]             # rotate the op ordering
    base = tempfile.mkdtemp(prefix="memforest_prop_")
    want = _run_uninterrupted(os.path.join(base, "ref"), ops,
                              snapshot_every=2).state_digest()
    store, _ = _run_with_crash_then_recover(os.path.join(base, "crash"),
                                            ops, crash_at)
    assert store.state_digest() == want


# ---------------------------------------------------------------------------
# maintenance plane
# ---------------------------------------------------------------------------
def test_plane_drains_deferred_flush_equivalently(wl):
    ref = MemForestSystem(MemForestConfig())
    ref.ingest_batch(wl.sessions)           # inline flush
    want = [r.answer for r in ref.query_batch(wl.queries)]

    mf = MemForestSystem(MemForestConfig())
    plane = MaintenancePlane(mf.forest, flush_trees_per_unit=3)
    mf.ingest_batch(wl.sessions, defer_flush=True)
    assert mf.forest.dirty_trees            # flush actually deferred
    assert plane.pending() > 0

    # bounded slices: each unit flushes at most flush_trees_per_unit trees
    first = plane.run_some(1)
    assert first["units"] == 1 and plane.trees_flushed <= 3
    plane.drain()
    assert not mf.forest.dirty_trees and plane.pending() == 0
    assert [r.answer for r in mf.query_batch(wl.queries)] == want
    assert plane.metrics()["maintenance_trees_flushed"] >= len(mf.forest.trees) // 2
    for t in mf.forest.trees.values():
        t.check_invariants()


def test_plane_queued_merge_runs_off_serve_path(wl, merge_wl):
    mf = _build(wl.sessions)
    before = mf.scale_stats()["facts"]
    plane = MaintenancePlane(mf.forest)
    plane.schedule_merge(_build(merge_wl.sessions), idempotency_key="pm")
    assert plane.pending() >= 1
    plane.drain()
    assert plane.merges_done == 1
    assert mf.scale_stats()["facts"] > before
    assert "pm" in mf.forest.applied_ops
    assert not mf.forest.dirty_trees        # merge's flush slice also drained


def test_plane_compaction_reclaims_tombstoned_slots(wl):
    mf = _build(wl.sessions)
    for s in wl.sessions[:4]:
        mf.delete_session(s.session_id)
    plane = MaintenancePlane(mf.forest, compact_min_dead_fraction=0.01)
    queued = plane.schedule_compaction()
    assert queued > 0
    nodes_before = mf.scale_stats()["nodes"]
    plane.drain()
    assert plane.compactions_done == queued
    assert plane.slots_reclaimed > 0
    assert mf.scale_stats()["nodes"] <= nodes_before
    for t in mf.forest.trees.values():
        t.check_invariants()
    for r in mf.query_batch(wl.queries):    # compacted forest still serves
        assert r.answer is not None


def test_plane_compaction_rides_durable_journal(tmp_path, wl):
    """A plane built with durable= routes compactions through the journal,
    so a crash right after the drain recovers the compacted state."""
    root = str(tmp_path / "store")
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root)
    store.ingest_batch(wl.sessions, idempotency_key="i")
    for s in wl.sessions[:4]:
        store.delete_session(s.session_id, idempotency_key=f"d:{s.session_id}")
    plane = MaintenancePlane(store.forest, compact_min_dead_fraction=0.01,
                             durable=store)
    queued = plane.schedule_compaction()
    assert queued > 0
    journal_before = len(read_journal(os.path.join(root, JOURNAL_NAME)))
    plane.drain()
    assert plane.compactions_done == queued
    assert len(read_journal(os.path.join(root, JOURNAL_NAME))) == \
        journal_before + queued                  # each compaction journaled
    want = store.state_digest()
    store.close()

    rec = DurableMemForest.open(root)
    assert rec.state_digest() == want
    rec.close()


def test_plane_background_thread_mode(wl):
    ref = _build(wl.sessions)
    want = [r.answer for r in ref.query_batch(wl.queries)]

    mf = MemForestSystem(MemForestConfig())
    plane = MaintenancePlane(mf.forest)
    plane.start_background(interval_s=0.001, budget_per_wake=2)
    try:
        with plane.lock:
            mf.ingest_batch(wl.sessions, defer_flush=True)
    finally:
        plane.stop_background(drain_first=True)
    assert not mf.forest.dirty_trees
    assert plane.units_run > 0
    assert [r.answer for r in mf.query_batch(wl.queries)] == want


def test_crash_injector_events_ride_trace_sink(tmp_path, wl, merge_wl):
    """Every durability tick mirrors into the trace sink as a
    ``durability/<event>`` point event — in the exact order of the legacy
    ``probe.trace`` list — and snapshot-protocol events nest under their
    ``journal.checkpoint`` span, so crash sweeps can assert span-level
    ordering straight from the trace."""
    from repro import obs as obs_mod
    from repro.obs import Observability

    sink = obs_mod.MemorySink()
    obs_mod.enable_tracing(sink)
    try:
        probe = CrashInjector(None, obs=Observability())
        store = DurableMemForest.open(str(tmp_path / "t"), crash=probe,
                                      snapshot_every=2)
        for op in _plan(wl, merge_wl):
            _apply(store, op)
        store.checkpoint()
        store.close()
    finally:
        obs_mod.disable_tracing()

    evs = sink.events("durability/")
    # the sink saw the full legacy trace, same events, same order
    assert [e["name"] for e in evs] == ["durability/" + t for t in probe.trace]
    assert [e["attrs"]["n"] for e in evs] == list(range(1, probe.events + 1))
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)

    # snapshot protocol events are parented to a journal.checkpoint span
    ckpt_ids = {r["span"] for r in sink.spans("journal.checkpoint")}
    assert ckpt_ids
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    for name in ("durability/snapshot:begin", "durability/snapshot:commit",
                 "durability/journal:rotate"):
        assert by_name[name], name
        assert all(e["span"] in ckpt_ids for e in by_name[name]), name
    # per-checkpoint protocol order: begin -> commit -> rotate
    for b, c, r in zip(by_name["durability/snapshot:begin"],
                       by_name["durability/snapshot:commit"],
                       by_name["durability/journal:rotate"]):
        assert b["span"] == c["span"] == r["span"]
        assert b["ts"] < c["ts"] < r["ts"]


def test_plane_merge_rides_durable_journal(tmp_path, wl, merge_wl):
    """A plane built with durable= routes queued merges through the
    journaled op (regression: memlint rule journaled-mutation caught the
    plane calling maintenance.migrate_merge directly, which a crash right
    after the drain would silently un-apply)."""
    root = str(tmp_path / "store")
    store = DurableMemForest(MemForestSystem(MemForestConfig()), root)
    store.ingest_batch(wl.sessions, idempotency_key="i")
    plane = MaintenancePlane(store.forest, durable=store)
    plane.schedule_merge(_build(merge_wl.sessions), idempotency_key="pm")
    plane.drain()
    assert plane.merges_done == 1
    recs = read_journal(os.path.join(root, JOURNAL_NAME))
    assert any(r["op"] == "migrate_merge" and r["key"] == "pm" for r in recs)
    assert "pm" in store.forest.applied_ops
    want = store.state_digest()
    store.close()

    rec = DurableMemForest.open(root)      # the merge survives recovery
    assert rec.state_digest() == want
    assert "pm" in rec.forest.applied_ops  # retries still dedup
    rec.close()
