"""Batched multi-session ingestion: state equivalence with the sequential
write path, cross-session encoder batching, and single-flush semantics."""
import numpy as np
import pytest

from repro.config import MemForestConfig
from repro.core.encoder import HashingEncoder
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload


def _fresh():
    cfg = MemForestConfig()
    return MemForestSystem(cfg, HashingEncoder(dim=cfg.embed_dim))


@pytest.fixture(scope="module")
def workload():
    return make_workload(num_entities=6, num_sessions=12, num_queries=30, seed=7)


@pytest.fixture(scope="module")
def pair(workload):
    seq = _fresh()
    for s in workload.sessions:
        seq.ingest_session(s)
    bat = _fresh()
    bat.ingest_batch(workload.sessions)
    return seq, bat


def test_equivalent_facts(pair):
    seq, bat = pair
    assert [f.key() for f in seq.forest.facts] == [f.key() for f in bat.forest.facts]
    assert [f.sources for f in seq.forest.facts] == [f.sources for f in bat.forest.facts]
    assert seq.forest.fact_alive == bat.forest.fact_alive


def test_equivalent_tree_state(pair):
    seq, bat = pair
    assert seq.forest.scale_stats() == bat.forest.scale_stats()
    assert set(seq.forest.trees) == set(bat.forest.trees)
    for k in seq.forest.trees:
        t1, t2 = seq.forest.trees[k], bat.forest.trees[k]
        assert t1.children == t2.children, k
        assert t1.payload == t2.payload, k
        # derived artifacts: summaries (emb + text) match after flush
        assert np.allclose(t1.emb[:t1._n], t2.emb[:t2._n], atol=1e-5), k
        assert t1.text == t2.text, k
        t2.check_invariants()


def test_equivalent_query_answers(pair, workload):
    seq, bat = pair
    for q in workload.queries:
        assert seq.query(q).answer == bat.query(q).answer


def test_one_encoder_forward_per_batch(workload):
    bat = _fresh()
    calls0 = bat.encoder.stats.calls
    bat.ingest_batch(workload.sessions)
    # ONE cross-session forward for the union of chunk + candidate texts,
    # not one (or two) per session
    assert bat.encoder.stats.calls - calls0 == 1

    seq = _fresh()
    calls0 = seq.encoder.stats.calls
    for s in workload.sessions:
        seq.ingest_session(s)
    assert seq.encoder.stats.calls - calls0 >= len(workload.sessions)


def test_one_flush_per_batch(workload):
    bat = _fresh()
    assert bat.forest.flush_calls == 0
    bat.ingest_batch(workload.sessions)
    assert bat.forest.flush_calls == 1
    assert not bat.forest.dirty_trees
    bat.ingest_batch(workload.sessions[:3])
    assert bat.forest.flush_calls == 2


def test_batch_of_one_matches_single(workload):
    a, b = _fresh(), _fresh()
    s = workload.sessions[0]
    a.ingest_session(s)
    b.ingest_batch([s])
    assert a.forest.scale_stats() == b.forest.scale_stats()
    assert [f.key() for f in a.forest.facts] == [f.key() for f in b.forest.facts]


def test_empty_batch_is_noop():
    sys_ = _fresh()
    assert sys_.ingest_batch([]) == []
    assert sys_.forest.flush_calls == 0


def test_read_triggered_refresh_defers_batch_flush(workload):
    sys_ = MemForestSystem(MemForestConfig(read_triggered_refresh=True))
    sys_.ingest_batch(workload.sessions)
    assert sys_.forest.flush_calls == 0
    assert sys_.forest.dirty_trees
    sys_.query(workload.queries[0])        # first reader pays the flush
    assert sys_.forest.flush_calls == 1
    assert not sys_.forest.dirty_trees


def test_incremental_batches_match_sequential(workload):
    """Batch boundaries are invisible: two ingest_batch calls over a split
    stream produce the same state as the per-session loop."""
    half = len(workload.sessions) // 2
    bat = _fresh()
    bat.ingest_batch(workload.sessions[:half])
    bat.ingest_batch(workload.sessions[half:])
    seq = _fresh()
    for s in workload.sessions:
        seq.ingest_session(s)
    assert seq.forest.scale_stats() == bat.forest.scale_stats()
    assert [f.key() for f in seq.forest.facts] == [f.key() for f in bat.forest.facts]
    for q in workload.queries[:10]:
        assert seq.query(q).answer == bat.query(q).answer


def test_serving_engine_ingest_lane(workload):
    """Write traffic rides the engine loop: queued sessions drain as ONE
    batched ingest per engine step, capped at max_ingest_batch."""
    from repro.serving.engine import ServeEngine

    mem = _fresh()

    class _NoModel:
        class cfg:
            num_layers = 0

        def prefill(self, p, b, L):
            raise AssertionError("no decode traffic in this test")

        def decode(self, p, b, c):
            raise AssertionError("no decode traffic in this test")

    eng = ServeEngine(_NoModel(), params=None, max_batch=2, memory=mem,
                      max_ingest_batch=8)
    for s in workload.sessions:
        eng.submit_session(s)
    eng.run_until_drained()
    assert eng.ingest_sessions == len(workload.sessions)
    # 12 sessions / cap 8 -> 2 engine turns, each ONE batched write
    assert eng.ingest_batches == 2
    assert mem.forest.flush_calls == 2
    assert eng.metrics()["mean_ingest_batch"] == pytest.approx(6.0)

    ref = _fresh()
    for s in workload.sessions:
        ref.ingest_session(s)
    assert ref.forest.scale_stats() == mem.forest.scale_stats()
