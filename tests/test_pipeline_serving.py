"""Data pipeline determinism/sharding + serving engine behaviour."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.models import get_model
from repro.serving.engine import BatchedEncoderServer, ServeEngine
from repro.core.encoder import HashingEncoder


def test_pipeline_deterministic_addressing():
    p = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=4, seed=3)
    a = p.batch_at(7)
    b = p.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_dp_shards_disjoint():
    ps = [TokenPipeline(vocab_size=1000, seq_len=16, global_batch=8,
                        dp_rank=r, dp_size=2, seed=0) for r in range(2)]
    b0, b1 = ps[0].batch_at(0), ps[1].batch_at(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    p = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=2,
                      corpus=["hello world this is a test " * 20])
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_serve_engine_drains_and_batches():
    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=4, max_len=64)
    rng = np.random.default_rng(0)
    n_req = 7
    for i in range(n_req):
        eng.submit(list(rng.integers(3, 400, size=4 + i % 3)), max_new_tokens=3)
    done = eng.run_until_drained()
    assert len(done) == n_req
    assert all(len(r.out_tokens) >= 1 for r in done)
    m = eng.metrics()
    assert m["mean_occupancy"] > 0.5      # continuous batching keeps slots busy
    assert m["decoded_tokens"] >= n_req * 1


def test_continuous_batching_preserves_active_decodes():
    """Admitting new requests mid-flight must not corrupt running decodes:
    outputs for identical prompts must be identical regardless of admission
    interleaving."""
    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = [5, 6, 7, 8]

    eng1 = ServeEngine(model, params, max_batch=2, max_len=32)
    eng1.submit(prompt, max_new_tokens=6)
    out_solo = eng1.run_until_drained()[0].out_tokens

    eng2 = ServeEngine(model, params, max_batch=2, max_len=32)
    eng2.submit(prompt, max_new_tokens=6)
    eng2.step()           # starts decoding request 0
    eng2.submit([9, 10, 11], max_new_tokens=3)  # admitted mid-flight
    out_mixed = next(
        r.out_tokens for r in eng2.run_until_drained() if r.prompt_tokens == prompt
    )
    assert out_solo == out_mixed


def test_prefix_cache_reuses_prefill():
    """Re-admitting the same prefix-keyed prompt block must hit the cache,
    skip the prefill launch, and decode identically."""
    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=2, max_len=32)
    prompts = [[5, 6, 7, 8], [5, 6, 7, 9]]   # shared instruction prefix

    for p in prompts:
        eng.submit(p, max_new_tokens=4, prefix_key="extract")
    out1 = sorted((r.prompt_tokens[-1], r.out_tokens)
                  for r in eng.run_until_drained())
    m1 = eng.metrics()
    assert m1["prefix_misses"] >= 1 and m1["prefix_hits"] == 0

    eng.finished.clear()
    for p in prompts:                          # identical admission recurs
        eng.submit(p, max_new_tokens=4, prefix_key="extract")
    out2 = sorted((r.prompt_tokens[-1], r.out_tokens)
                  for r in eng.run_until_drained())
    m2 = eng.metrics()
    assert m2["prefix_hits"] >= 1
    assert m2["prefills_reused"] >= 1
    assert out1 == out2                        # reuse is output-invariant


def test_query_lane_drains_batched():
    """Queries queued on the engine drain as ONE query_batch per engine
    step and return the same results as calling the memory directly."""
    from repro.config import MemForestConfig
    from repro.core.memforest import MemForestSystem
    from repro.data.synthetic import make_workload

    wl = make_workload(num_entities=4, num_sessions=6,
                       transitions_per_entity=3, num_queries=10, seed=21)
    mf = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        mf.ingest_session(s)
    want = [r.answer for r in mf.query_batch(wl.queries)]

    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=2, max_len=32, memory=mf)
    rids = [eng.submit_query(q) for q in wl.queries]
    eng.submit([5, 6, 7], max_new_tokens=2)    # decode traffic shares the loop
    eng.run_until_drained()

    m = eng.metrics()
    assert m["queries_served"] == len(wl.queries)
    assert m["query_batches"] == 1             # one batched drain, not N
    got = [eng.pop_query_result(r).answer for r in rids]
    assert got == want
    assert not eng.query_results                # consumed: nothing retained


def test_query_lane_requires_memory():
    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=2, max_len=32)
    with pytest.raises(RuntimeError):
        eng.submit_query(object())


def test_batched_encoder_server_prefix_accounting():
    enc = HashingEncoder(dim=64)
    srv = BatchedEncoderServer(enc)
    out = srv.encode_chunks(["chunk one text", "chunk two text", "chunk three"])
    assert out.shape == (3, 64)
    assert srv.prefix_tokens_saved > 0
    assert enc.stats.calls == 1   # one batched forward, not three


def test_maintenance_lane_defers_flush_off_serve_loop():
    """With a MaintenancePlane attached, ingest drains defer their flush and
    the engine retires refresh work in bounded slices between decode steps —
    answers stay identical to the inline-flush engine."""
    from repro.config import MemForestConfig
    from repro.core.maintenance_plane import MaintenancePlane
    from repro.core.memforest import MemForestSystem
    from repro.data.synthetic import make_workload

    wl = make_workload(num_entities=4, num_sessions=6,
                       transitions_per_entity=3, num_queries=10, seed=22)
    ref = MemForestSystem(MemForestConfig())
    ref.ingest_batch(wl.sessions)
    want = [r.answer for r in ref.query_batch(wl.queries)]

    mf = MemForestSystem(MemForestConfig())
    plane = MaintenancePlane(mf.forest, flush_trees_per_unit=2)
    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=2, max_len=32, memory=mf,
                      maintenance=plane, maintenance_budget=2)
    for s in wl.sessions:
        eng.submit_session(s)
    eng.submit([5, 6, 7], max_new_tokens=2)    # decode traffic shares the loop
    eng.run_until_drained()                    # lane retires the deferred flush

    m = eng.metrics()
    assert m["maintenance_turns"] > 0          # lane actually ran slices
    assert m["maintenance_pending"] == 0       # drained before exit
    assert not mf.forest.dirty_trees           # readers won't pay the flush

    rids = [eng.submit_query(q) for q in wl.queries]
    eng.run_until_drained()
    got = [eng.pop_query_result(r).answer for r in rids]
    assert got == want
