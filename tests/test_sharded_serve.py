"""Mesh-sharded serve: exact parity with single-device, deterministic
tie-break, geometric device-cache growth, and the mesh=None fast path.

The multi-device checks run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (this pytest process
must keep seeing exactly 1 device — test_dryrun_smoke enforces that); the
actual assertions live in tests/sharded_parity_check.py. Everything else
here runs in-process on the single device.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# deterministic tie-break (satellite: applies to single-device topk too)
# ---------------------------------------------------------------------------
def test_merge_topk_breaks_ties_by_ascending_index():
    import jax.numpy as jnp
    from repro.kernels.topk_sim import merge_topk

    # candidate pool with duplicate scores in shuffled index order
    vals = jnp.asarray([[1.0, 3.0, 3.0, 2.0, 3.0, 1.0]], jnp.float32)
    idx = jnp.asarray([[50, 40, 7, 12, 19, 3]], jnp.int32)
    v, i = merge_topk(vals, idx, 4)
    assert np.allclose(np.asarray(v)[0], [3.0, 3.0, 3.0, 2.0])
    # ties at 3.0 resolve to ascending global row ids: 7 < 19 < 40
    assert np.asarray(i)[0].tolist() == [7, 19, 40, 12]


def test_merge_topk_masks_padding():
    import jax.numpy as jnp
    from repro.kernels.topk_sim import NEG_INF, merge_topk

    vals = jnp.asarray([[2.0, NEG_INF, 1.0, NEG_INF]], jnp.float32)
    idx = jnp.asarray([[4, -1, 9, -1]], jnp.int32)
    v, i = merge_topk(vals, idx, 3)
    assert np.asarray(i)[0].tolist() == [4, 9, -1]


@pytest.mark.parametrize("impl", ["reference", "pallas_interpret"])
def test_single_device_topk_tie_break(impl):
    """Duplicate key rows must surface in ascending-row-id order for every
    kernel impl — the contract the sharded merge relies on for exactness."""
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    row = rng.standard_normal(32).astype(np.float32)
    other = rng.standard_normal((64, 32)).astype(np.float32)
    keys = np.concatenate([other, row[None], other[-8:], row[None]])
    dup_a, dup_b = 64, 73  # identical rows -> identical scores
    q = row[None] / np.linalg.norm(row)
    vals, idx = ops.topk_sim(q, keys, 4, impl=impl)
    idx = np.asarray(idx)[0]
    assert dup_a in idx and dup_b in idx, f"duplicate rows missing: {idx}"
    pos_a, pos_b = list(idx).index(dup_a), list(idx).index(dup_b)
    assert pos_a < pos_b, f"tie not broken by ascending id: {idx}"
    # tail duplicates (rows 65..72 copy rows 56..63): lower id always first
    for g in range(65, 73):
        if g in idx and (g - 9) in idx:
            assert list(idx).index(g - 9) < list(idx).index(g)


# ---------------------------------------------------------------------------
# geometric device-cache growth (satellite: no full re-upload on growth)
# ---------------------------------------------------------------------------
def test_device_cache_grows_without_reupload():
    from repro.config import MemForestConfig
    from repro.core.memforest import MemForestSystem
    from repro.data.synthetic import make_workload

    wl = make_workload(num_entities=4, num_sessions=12, num_queries=6, seed=3)
    mf = MemForestSystem(MemForestConfig())
    third = len(wl.sessions) // 3
    for s in wl.sessions[:third]:
        mf.ingest_session(s)
    mf.query_batch(wl.queries)          # builds the device caches
    up0, gr0 = mf.forest.index_uploads, mf.forest.index_grows
    assert up0 > 0 and gr0 == 0
    for s in wl.sessions[third:]:
        mf.ingest_session(s)            # host capacity grows past cache cap
    res = mf.query_batch(wl.queries)
    assert mf.forest.index_uploads == up0, \
        "capacity growth re-uploaded the whole index"
    assert mf.forest.index_grows >= 1

    fresh = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        fresh.ingest_session(s)
    for a, b in zip(res, fresh.query_batch(wl.queries)):
        assert a.answer == b.answer and a.evidence == b.evidence


def test_grow_rows_preserves_existing():
    import jax.numpy as jnp
    from repro.kernels import ops

    arr = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    grown = ops.grow_rows(arr, 4)
    assert grown.shape == (8, 3)
    assert np.array_equal(np.asarray(grown[:4]), np.asarray(arr))
    assert not np.asarray(grown[4:]).any()


# ---------------------------------------------------------------------------
# mesh plumbing on a single device (fast-path fallbacks)
# ---------------------------------------------------------------------------
def test_make_data_mesh_single_device_is_none():
    from repro.launch.mesh import make_data_mesh

    assert make_data_mesh() is None      # 1 visible device
    assert make_data_mesh(1) is None
    assert make_data_mesh(4) is None     # capped at available


def test_set_mesh_none_is_identity():
    from repro.config import MemForestConfig
    from repro.core.memforest import MemForestSystem
    from repro.data.synthetic import make_workload

    wl = make_workload(num_entities=3, num_sessions=5, num_queries=5, seed=9)
    a = MemForestSystem(MemForestConfig())
    b = MemForestSystem(MemForestConfig())
    b.set_mesh(None)
    for s in wl.sessions:
        a.ingest_session(s)
        b.ingest_session(s)
    for ra, rb in zip(a.query_batch(wl.queries), b.query_batch(wl.queries)):
        assert ra.answer == rb.answer and ra.evidence == rb.evidence


def test_sharded_serve_config_single_device_fallback():
    """ShardedServeConfig on a 1-device host degrades to mesh=None serve."""
    from repro.config import MemForestConfig
    from repro.core.memforest import MemForestSystem
    from repro.serving.engine import ServeEngine, ShardedServeConfig

    class _NoModel:
        class cfg:
            num_layers = 0

        def prefill(self, params, batch, max_len):
            import jax.numpy as jnp
            B = batch["tokens"].shape[0]
            return jnp.zeros((B, 4)), {}

        def decode(self, params, batch, cache):
            import jax.numpy as jnp
            B = batch["tokens"].shape[0]
            return jnp.zeros((B, 4)), cache

    mf = MemForestSystem(MemForestConfig())
    eng = ServeEngine(_NoModel(), None, memory=mf,
                      sharded=ShardedServeConfig(devices=4))
    assert eng.serve_mesh is None
    assert mf.forest.mesh is None
    assert eng.metrics()["serve_devices"] == 1


# ---------------------------------------------------------------------------
# multi-device parity (subprocess: forced host device count)
# ---------------------------------------------------------------------------
def test_multi_device_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "sharded_parity_check.py"),
         "--meshes", "2,4"],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT,
    )
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "PARITY OK" in r.stdout
