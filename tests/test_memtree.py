"""Property-based tests for MemTree/Forest invariants (hypothesis)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _propcheck import given, settings, strategies as st

from repro.config import MemForestConfig
from repro.core.forest import Forest
from repro.core.memtree import TreeArena

DIM = 16


def _emb(rng, n=1):
    e = rng.normal(size=(n, DIM)).astype(np.float32)
    e /= np.linalg.norm(e, axis=1, keepdims=True) + 1e-6
    return e


@settings(max_examples=60, deadline=None)
@given(
    ts_list=st.lists(st.floats(0, 1000, allow_nan=False), min_size=1, max_size=120),
    k=st.integers(3, 16),
)
def test_insert_invariants(ts_list, k):
    """Temporal leaf order, balance bound, parent ranges, level uniformity —
    for ANY insertion order and branching factor."""
    rng = np.random.default_rng(0)
    t = TreeArena(0, "entity:x", "entity", k, DIM)
    for i, ts in enumerate(ts_list):
        t.insert_leaf(i, ts, _emb(rng)[0], f"fact {i}")
        t.check_invariants()
    assert t.num_leaves == len(ts_list)
    # every payload is reachable exactly once
    leaves = t.leaves_in_order()
    assert sorted(t.payload[l] for l in leaves) == sorted(range(len(ts_list)))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 80),
    k=st.integers(3, 8),
    delete_frac=st.floats(0.1, 0.9),
)
def test_delete_invariants(n, k, delete_frac, rng):
    t = TreeArena(0, "entity:x", "entity", k, DIM)
    leaves = []
    for i in range(n):
        leaves.append(t.insert_leaf(i, float(i), _emb(rng)[0], f"f{i}"))
    del_ids = list(np.random.default_rng(1).choice(
        leaves, size=max(1, int(n * delete_frac)), replace=False))
    for l in del_ids:
        t.delete_leaf(int(l))
        t.check_invariants()
    assert t.num_leaves == n - len(del_ids)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 100), k=st.integers(3, 8))
def test_dirty_path_coalescing(n, k):
    """After any batch of inserts, dirty set = union of leaf-to-root paths;
    ancestors of any dirty node are dirty (coalescing invariant)."""
    rng = np.random.default_rng(2)
    t = TreeArena(0, "s", "entity", k, DIM)
    for i in range(n):
        t.insert_leaf(i, float(rng.random() * 100), _emb(rng)[0], f"f{i}")
    for node in t.dirty:
        p = t.parent[node]
        if p != -1 and t.alive[node]:
            assert p in t.dirty, "dirty node with clean parent"


def test_height_is_logarithmic(rng):
    t = TreeArena(0, "s", "entity", 8, DIM)
    import math
    for i in range(1000):
        t.insert_leaf(i, float(i), _emb(rng)[0], f"f{i}")
    assert t.height <= math.ceil(math.log(1000, 4)) + 1  # k/2 = 4 min fill
    t.check_invariants()


def test_flush_refreshes_all_dirty(rng):
    cfg = MemForestConfig(branching_factor=4, embed_dim=DIM)
    f = Forest(cfg)
    for i in range(40):
        f.insert_item("entity:bob", "entity", "fact", i, float(i),
                      _emb(rng)[0], f"fact number {i}")
    stats = f.flush()
    tree = f.trees["entity:bob"]
    assert not tree.dirty
    assert stats["refreshes"] > 0
    assert stats["levels"] == tree.height
    # summaries are unit-norm and nonzero for every internal node
    for nid in range(tree._n):
        if tree.alive[nid] and tree.level[nid] > 0:
            assert abs(np.linalg.norm(tree.emb[nid]) - 1.0) < 1e-3


def test_refresh_summary_consistency(rng):
    """Parent embedding == normalized mean of child embeddings (Algorithm 1
    semantics), verified against a manual recomputation."""
    cfg = MemForestConfig(branching_factor=4, embed_dim=DIM)
    f = Forest(cfg)
    for i in range(20):
        f.insert_item("entity:a", "entity", "fact", i, float(i),
                      _emb(rng)[0], f"f{i}")
    f.flush()
    t = f.trees["entity:a"]
    for nid in range(t._n):
        if not t.alive[nid] or t.level[nid] == 0:
            continue
        kids = t.children[nid]
        mean = np.mean([t.emb[c] for c in kids], axis=0)
        mean /= np.linalg.norm(mean) + 1e-6
        np.testing.assert_allclose(t.emb[nid], mean, atol=1e-4)


def test_lazy_coalescing_saves_refreshes(rng):
    """Batch flush must refresh each shared ancestor ONCE (paper Fig. 6a)."""
    cfg = MemForestConfig(branching_factor=4, embed_dim=DIM)
    lazy = Forest(cfg)
    eager = Forest(cfg)
    for i in range(64):
        for fst in (lazy, eager):
            fst.insert_item("entity:a", "entity", "fact", i, float(i),
                            _emb(rng)[0], f"f{i}")
        eager.eager_refresh_path("entity:a")
    lazy.flush()
    assert lazy.summary_refreshes < eager.summary_refreshes


def test_level_parallel_equals_sequential(rng):
    """level_parallel=True/False produce identical summaries (parallelism is
    a schedule, not a semantics change)."""
    cfg = MemForestConfig(branching_factor=4, embed_dim=DIM)
    a, b = Forest(cfg), Forest(cfg)
    for i in range(50):
        e = _emb(rng)[0]
        a.insert_item("entity:x", "entity", "fact", i, float(i), e, f"f{i}")
        b.insert_item("entity:x", "entity", "fact", i, float(i), e, f"f{i}")
    ra = a.flush(level_parallel=True)
    rb = b.flush(level_parallel=False)
    ta, tb = a.trees["entity:x"], b.trees["entity:x"]
    np.testing.assert_allclose(ta.emb[:ta._n], tb.emb[:tb._n], atol=1e-5)
    assert ra["kernel_calls"] < rb["kernel_calls"]  # batching actually batched


def test_summaries_fresh_across_interleaved_flushes(rng):
    """Splits must dirty-mark the split node's ancestors: with a flush
    between every insert, every internal summary still equals the
    recomputation from its (possibly restructured) children."""
    cfg = MemForestConfig(branching_factor=4, embed_dim=DIM)
    f = Forest(cfg)
    for i in range(40):
        f.insert_item("entity:a", "entity", "fact", i, float(i),
                      _emb(rng)[0], f"f{i}")
        f.flush()                      # dirty set cleared every insert
    t = f.trees["entity:a"]
    for nid in range(t._n):
        if not t.alive[nid] or t.level[nid] == 0:
            continue
        kids = t.children[nid]
        mean = np.mean([t.emb[c] for c in kids], axis=0)
        mean /= np.linalg.norm(mean) + 1e-6
        np.testing.assert_allclose(t.emb[nid], mean, atol=1e-4)
