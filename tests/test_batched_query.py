"""Batched multi-query retrieval: equivalence with single-query path and
encoder-call reduction."""
import pytest

from repro.config import MemForestConfig
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload


@pytest.fixture(scope="module")
def built():
    wl = make_workload(num_entities=5, num_sessions=8,
                       transitions_per_entity=3, num_queries=20, seed=5)
    mf = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        mf.ingest_session(s)
    return mf, wl


@pytest.mark.parametrize("mode", ["flat", "llm+planner"])
def test_batched_matches_single(built, mode):
    # retrieve/retrieve_batch share the lane engine: answers are IDENTICAL,
    # not merely in high agreement (see test_query_parity.py for the full
    # facts/evidence parity suite)
    mf, wl = built
    singles = [mf.query(q, mode=mode).answer for q in wl.queries]
    batched = [r.answer for r in mf.query_batch(wl.queries, mode=mode)]
    assert singles == batched


def test_batched_uses_fewer_encoder_calls(built):
    mf, wl = built
    qs = wl.queries[:10]
    c0 = mf.encoder.stats.calls
    for q in qs:
        mf.query(q, mode="emb")
    seq_calls = mf.encoder.stats.calls - c0
    c0 = mf.encoder.stats.calls
    mf.query_batch(qs, mode="emb")
    batch_calls = mf.encoder.stats.calls - c0
    assert batch_calls < seq_calls / 2, (batch_calls, seq_calls)


def test_batched_accuracy(built):
    mf, wl = built
    res = mf.query_batch(wl.queries, mode="llm+planner")
    acc = sum(int(r.answer.strip().lower() == q.gold.strip().lower())
              for r, q in zip(res, wl.queries)) / len(wl.queries)
    assert acc >= 0.8, acc
