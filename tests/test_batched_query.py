"""Batched multi-query retrieval: equivalence with single-query path and
encoder-call reduction."""
import pytest

from repro.config import MemForestConfig
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload


@pytest.fixture(scope="module")
def built():
    wl = make_workload(num_entities=5, num_sessions=8,
                       transitions_per_entity=3, num_queries=20, seed=5)
    mf = MemForestSystem(MemForestConfig())
    for s in wl.sessions:
        mf.ingest_session(s)
    return mf, wl


@pytest.mark.parametrize("mode", ["flat", "llm+planner"])
def test_batched_matches_single(built, mode):
    # retrieve/retrieve_batch share the lane engine: answers are IDENTICAL,
    # not merely in high agreement (see test_query_parity.py for the full
    # facts/evidence parity suite)
    mf, wl = built
    singles = [mf.query(q, mode=mode).answer for q in wl.queries]
    batched = [r.answer for r in mf.query_batch(wl.queries, mode=mode)]
    assert singles == batched


def test_batched_uses_fewer_encoder_calls(built):
    mf, wl = built
    qs = wl.queries[:10]
    c0 = mf.encoder.stats.calls
    for q in qs:
        mf.query(q, mode="emb")
    seq_calls = mf.encoder.stats.calls - c0
    c0 = mf.encoder.stats.calls
    mf.query_batch(qs, mode="emb")
    batch_calls = mf.encoder.stats.calls - c0
    assert batch_calls < seq_calls / 2, (batch_calls, seq_calls)


def test_batched_accuracy(built):
    mf, wl = built
    res = mf.query_batch(wl.queries, mode="llm+planner")
    acc = sum(int(r.answer.strip().lower() == q.gold.strip().lower())
              for r, q in zip(res, wl.queries)) / len(wl.queries)
    assert acc >= 0.8, acc


def test_browse_beam_tie_break_prefers_lowest_child_index():
    """Equal browse scores must resolve to the LOWEST child ids (stable
    argsort) — regression for the unstable `np.argsort(-sims)` the memlint
    topk-tiebreak rule caught in the lane browse; an unstable sort makes
    beam membership an implementation detail of the sort algorithm, which
    is exactly what broke exact mesh/single-device parity before PR 7."""
    import numpy as np

    from repro.core.memtree import TreeArena
    from repro.core.retrieval import Retriever, _Lane

    cfg = MemForestConfig()
    tree = TreeArena(0, "t", "entity", 4, cfg.embed_dim)
    v = np.zeros(cfg.embed_dim, np.float32)
    v[0] = 1.0
    leaves = [tree._alloc(0, (0.0, 1.0), text=f"leaf{i}", emb=v)
              for i in range(6)]              # identical embeddings: all tie
    root = tree._alloc(1, (0.0, 1.0), text="root", emb=v)
    tree.children[root] = list(leaves)
    for leaf in leaves:
        tree.parent[leaf] = root
    tree.root = root

    class _FlatForest:
        mesh = None
        mesh_axis = None
        kernel_impl = "reference"

    r = Retriever(_FlatForest(), encoder=None, config=cfg)
    lane = _Lane(0, tree, v, None, q_words=set())
    r._browse_lanes([lane])
    assert set(lane.collected) == set(leaves[:cfg.browse_beam]), \
        "tied scores must keep ascending child-id order"
