"""Roofline analytics: model-flops identities and term sanity."""
import pytest

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.launch.roofline import analytic_bytes, analytic_flops, roofline_terms


def test_dense_flops_close_to_6nd():
    """For a dense LM at moderate context, analytic hlo-equivalent train
    FLOPs should be within ~2x of the 6ND rule (remat adds ~4/3, attention
    adds the quadratic term)."""
    cfg = get_config("llama3_8b")
    shape = SHAPES["train_4k"]
    fl = analytic_flops(cfg, shape)
    ratio = fl.hlo_equiv / fl.model_flops
    assert 1.0 < ratio < 2.2, ratio


def test_moe_active_params_flops():
    cfg = get_config("qwen3_moe_235b")
    shape = SHAPES["train_4k"]
    fl = analytic_flops(cfg, shape)
    assert fl.model_flops < 6 * cfg.param_count() * shape.seq_len * shape.global_batch * 0.25


def test_decode_is_memory_bound():
    cfg = get_config("llama3_8b")
    t = roofline_terms(cfg, SHAPES["decode_32k"], num_devices=256, tp=16,
                       collective_bytes_per_dev=0.0)
    assert t["dominant"] == "memory"
    assert t["bytes_cache"] > t["bytes_weights"] * 0.5


def test_ssm_decode_state_not_quadratic():
    """RWKV6 long-context decode bytes are context-independent (state-based)."""
    cfg = get_config("rwkv6_1b6")
    b32 = analytic_bytes(cfg, SHAPES["decode_32k"], num_devices=256, tp=16)
    import dataclasses
    long_shape = SHAPES["long_500k"]
    blong = analytic_bytes(cfg, long_shape, num_devices=256, tp=16)
    # per-sequence state traffic identical despite 16x context
    per_seq_32 = b32.cache / SHAPES["decode_32k"].global_batch * (256 / 16)
    per_seq_long = blong.cache / long_shape.global_batch * (256 / 16)
    assert abs(per_seq_32 - per_seq_long) / per_seq_long < 1e-6


def test_terms_scale_with_devices():
    cfg = get_config("llama3_8b")
    t256 = roofline_terms(cfg, SHAPES["train_4k"], num_devices=256, tp=16,
                          collective_bytes_per_dev=1e9)
    t512 = roofline_terms(cfg, SHAPES["train_4k"], num_devices=512, tp=16,
                          collective_bytes_per_dev=1e9)
    assert t512["compute_s"] < t256["compute_s"]
