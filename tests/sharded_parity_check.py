"""Multi-device serve parity checker (NOT a pytest module — run as a script
by tests/test_sharded_serve.py in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``, so the forced
virtual devices never leak into the pytest process).

Checks, for each requested mesh size, EXACT parity against mesh=None:
  * flush parity — ingesting under a mesh produces bitwise-identical tree
    summary embeddings and identical node texts (the sharded tree_refresh
    path is row-local math);
  * retrieval parity — ``query_batch`` answers + evidence and single
    ``query`` answers match for all six browse modes (sharded topk_sim +
    sharded browse lanes);
  * growth parity — ingest-after-query grows the sharded device cache in
    place (no re-upload) and results still match a fresh system;
  * uneven shards — fact counts not divisible by the mesh size, and a tiny
    workload with fewer facts than devices, both pad correctly.

Exits 0 and prints "PARITY OK" on success; any mismatch raises.
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--meshes", default="2,4",
                    help="comma-separated data-axis sizes to check")
    args = ap.parse_args()
    sizes = [int(s) for s in args.meshes.split(",") if s]

    import jax
    import numpy as np

    from repro.config import MemForestConfig
    from repro.core.memforest import MemForestSystem
    from repro.data.synthetic import make_workload
    from repro.launch.mesh import make_data_mesh

    assert len(jax.devices()) >= max(sizes), (
        f"need {max(sizes)} devices, got {len(jax.devices())} — "
        "run with XLA_FLAGS=--xla_force_host_platform_device_count=N")

    MODES = ["flat", "root-only", "emb", "emb+planner", "llm", "llm+planner"]

    def build(wl, mesh):
        mf = MemForestSystem(MemForestConfig())
        mf.set_mesh(mesh)
        for s in wl.sessions:
            mf.ingest_session(s)
        return mf

    def check_trees(base, other, tag):
        assert set(base.forest.trees) == set(other.forest.trees), tag
        for tid, tree in base.forest.trees.items():
            t2 = other.forest.trees[tid]
            n = tree._n
            assert t2._n == n, (tag, tid)
            assert np.array_equal(tree.emb[:n], t2.emb[:n]), (tag, tid)
            assert tree.text == t2.text, (tag, tid)

    def check_queries(base, other, queries, tag):
        assert queries, f"{tag}: workload produced no queries"
        for mode in MODES:
            r0 = base.query_batch(queries, mode=mode)
            r1 = other.query_batch(queries, mode=mode)
            for a, b in zip(r0, r1):
                assert a.answer == b.answer, (tag, mode, a.answer, b.answer)
                assert a.evidence == b.evidence, (tag, mode)
        a = base.query(queries[0])
        b = other.query(queries[0])
        assert a.answer == b.answer and a.evidence == b.evidence, tag

    # -- main workload: enough facts that every shard holds many rows ------
    wl = make_workload(num_entities=5, num_sessions=9,
                       transitions_per_entity=3, num_queries=10, seed=11)
    base = build(wl, None)
    for S in sizes:
        mesh = make_data_mesh(S)
        assert mesh is not None and mesh.devices.size == S
        mf = build(wl, mesh)
        check_trees(base, mf, f"S={S}")
        check_queries(base, mf, wl.queries, f"S={S}")
        print(f"mesh={S}: flush + all-mode query parity OK")

        # growth under mesh: query (build cache), ingest more, query again
        wl2 = make_workload(num_entities=5, num_sessions=4,
                            transitions_per_entity=2, num_queries=4, seed=12)
        mf.query_batch(wl.queries)
        up0, gr0 = mf.forest.index_uploads, mf.forest.index_grows
        for s in wl2.sessions:
            mf.ingest_session(s)
        r = mf.query_batch(wl.queries)
        assert mf.forest.index_uploads == up0, \
            f"S={S}: capacity growth re-uploaded the sharded cache"
        assert mf.forest.index_grows > gr0, f"S={S}: no sharded growth"
        fresh = MemForestSystem(MemForestConfig())
        for s in list(wl.sessions) + list(wl2.sessions):
            fresh.ingest_session(s)
        rf = fresh.query_batch(wl.queries)
        for a, b in zip(r, rf):
            assert a.answer == b.answer and a.evidence == b.evidence, f"S={S}"
        print(f"mesh={S}: in-place sharded growth parity OK")

    # -- uneven shards: fact count not divisible by the mesh size ----------
    wl_odd = make_workload(num_entities=1, num_sessions=2,
                           transitions_per_entity=2, num_queries=6, seed=6)
    base_odd = build(wl_odd, None)
    n_facts = len(base_odd.forest.facts)
    assert any(n_facts % S for S in sizes), \
        f"odd workload regressed: {n_facts} facts divides every mesh size"
    for S in sizes:
        mf = build(wl_odd, make_data_mesh(S))
        check_queries(base_odd, mf, wl_odd.queries, f"odd S={S}")
    print(f"uneven-shard parity OK ({n_facts} facts)")

    # -- fewer valid rows than devices (emptiest shards own zero rows) -----
    from repro.kernels import ops, shard_ops

    S_max = max(sizes)
    rng = np.random.default_rng(5)
    tiny = rng.standard_normal((S_max - 1, 16)).astype(np.float32)
    q = np.asarray(ops.normalize_rows(
        rng.standard_normal((2, 16), dtype=np.float32)))
    mesh = make_data_mesh(S_max)
    cap = shard_ops.pad_rows(8, S_max)
    sharded = shard_ops.upload_sharded(tiny, cap, mesh)
    v1, i1 = shard_ops.sharded_topk_sim(
        q, sharded, 4, mesh=mesh, num_valid=tiny.shape[0])
    dense = ops.normalize_rows(
        np.pad(tiny, ((0, cap - tiny.shape[0]), (0, 0))))
    v0, i0 = ops.topk_sim(q, dense, 4, normalize=False,
                          num_valid=tiny.shape[0])
    assert np.array_equal(np.asarray(i0), np.asarray(i1)), (i0, i1)
    assert np.allclose(np.asarray(v0), np.asarray(v1))
    print(f"tiny-index parity OK ({tiny.shape[0]} rows on {S_max} devices)")

    print("PARITY OK")


if __name__ == "__main__":
    sys.exit(main())
