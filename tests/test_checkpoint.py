"""Checkpointing: roundtrip fidelity, atomicity, torn-write recovery, GC."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import checkpoint as ckpt


def _state(key=0):
    k = jax.random.key(key)
    return {
        "params": {
            "w": jax.random.normal(k, (16, 8), jnp.float32),
            "b": jnp.zeros((8,), jnp.bfloat16),
            "layers": {"ln": jnp.ones((4, 8))},
        },
        "opt": {"m": jnp.full((16, 8), 0.5), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 10, s, extra={"arch": "x"})
    s2, extra = ckpt.restore(str(tmp_path), s)
    assert extra == {"arch": "x"}
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_and_gc(tmp_path):
    s = _state()
    for step in (10, 20, 30, 40):
        ckpt.save(str(tmp_path), step, s, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 40
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000030", "step_00000040"]


def test_torn_checkpoint_recovery(tmp_path):
    """A crash mid-write leaves .tmp; restore falls back to the previous
    complete checkpoint."""
    s = _state()
    ckpt.save(str(tmp_path), 10, s)
    # simulate a torn write at step 20
    os.makedirs(tmp_path / "step_00000020.tmp")
    with open(tmp_path / "step_00000020.tmp" / "shard_0000.bin", "wb") as f:
        f.write(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 10
    s2, _ = ckpt.restore(str(tmp_path), s)
    np.testing.assert_array_equal(
        np.asarray(s["params"]["w"]), np.asarray(s2["params"]["w"]))


def test_corrupt_latest_marker_falls_back(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 10, s)
    ckpt.save(str(tmp_path), 20, s)
    # LATEST points at a checkpoint whose manifest was lost
    shutil.rmtree(tmp_path / "step_00000020")
    os.makedirs(tmp_path / "step_00000020")
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_restore_into_shapedtypestructs(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 5, s)
    sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), s)
    s2, _ = ckpt.restore(str(tmp_path), sds)
    np.testing.assert_array_equal(np.asarray(s["opt"]["m"]), np.asarray(s2["opt"]["m"]))
