"""Training substrate: optimization, microbatching, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.models import get_model
from repro.training import grad_compress, optimizer
from repro.training.train_loop import init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    return cfg, model, pipe


def test_loss_decreases_on_fixed_batch(setup):
    cfg, model, pipe = setup
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=50, warmup_steps=2)
    state = init_train_state(model, tcfg, jax.random.key(0))
    step = jax.jit(make_train_step(model, tcfg))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_microbatching_matches_full_batch(setup):
    cfg, model, pipe = setup
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(1).items()}
    t_full = TrainConfig(learning_rate=1e-3, microbatch_size=0)
    t_micro = TrainConfig(learning_rate=1e-3, microbatch_size=2)
    s0 = init_train_state(model, t_full, jax.random.key(0))
    s1, m1 = jax.jit(make_train_step(model, t_full))(s0, batch)
    s2, m2 = jax.jit(make_train_step(model, t_micro))(s0, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), atol=2e-2, rtol=2e-2)
    w1 = np.asarray(jax.tree.leaves(s1["params"])[0], np.float32)
    w2 = np.asarray(jax.tree.leaves(s2["params"])[0], np.float32)
    np.testing.assert_allclose(w1, w2, atol=5e-2, rtol=5e-2)


def test_lr_schedule():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lr0 = float(optimizer.lr_schedule(jnp.asarray(0), tcfg))
    lr10 = float(optimizer.lr_schedule(jnp.asarray(10), tcfg))
    lr100 = float(optimizer.lr_schedule(jnp.asarray(100), tcfg))
    assert lr0 < lr10
    assert abs(lr10 - 1e-3) < 1e-5
    assert lr100 < lr10 * 0.2


def test_grad_clip():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, norm = optimizer.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert abs(float(optimizer.global_norm(clipped)) - 1.0) < 1e-4


def test_topk_compression_error_feedback():
    """Error feedback: the residual stays BOUNDED and the running average of
    compressed grads converges to the true grad (nothing permanently lost)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)}
    err = grad_compress.init_error_state(g)
    total_comp = jnp.zeros((64,))
    devs = []
    for t in range(1, 121):
        comp, err, _ = grad_compress.compress(g, err, method="topk", ratio=0.1)
        total_comp = total_comp + comp["w"]
        if t in (30, 120):
            devs.append(float(jnp.max(jnp.abs(total_comp / t - g["w"]))))
    # residual bounded (error feedback flushes every coordinate eventually)
    assert float(jnp.max(jnp.abs(err["w"]))) < 30.0
    # running average converges: deviation shrinks ~1/T
    assert devs[1] < devs[0] / 2, devs
    assert devs[1] < 0.5


def test_int8_compression_bounded_error():
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(128,)), jnp.float32)}
    err = grad_compress.init_error_state(g)
    comp, err, m = grad_compress.compress(g, err, method="int8")
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.max(jnp.abs(comp["w"] - g["w"]))) <= scale * 0.51
    assert float(m["compress_ratio"]) == 0.5


def test_compressed_training_still_learns(setup):
    cfg, model, pipe = setup
    tcfg = TrainConfig(learning_rate=1e-3, grad_compression="topk",
                       compression_ratio=0.25)
    state = init_train_state(model, tcfg, jax.random.key(0))
    step = jax.jit(make_train_step(model, tcfg))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3
