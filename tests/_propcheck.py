"""Minimal hypothesis stand-in so property tests always collect.

When the real ``hypothesis`` wheel is absent, tests fall back to this shim:
a seeded-random example generator with ``given``/``settings``-compatible
decorators covering the small strategy surface the suite uses
(``integers``, ``floats``, ``lists``). Examples are deterministic per test
(seeded from the test name) so failures reproduce.
"""
from __future__ import annotations

import inspect
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, gen):
        self._gen = gen

    def example(self, rng):
        return self._gen(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(min_value, max_value, allow_nan=False, allow_infinity=False,
            width=64):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _lists(elements, min_size=0, max_size=10):
    def gen(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(gen)


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def _sampled_from(options):
    opts = list(options)
    return _Strategy(lambda rng: opts[int(rng.integers(0, len(opts)))])


strategies = types.SimpleNamespace(
    integers=_integers,
    floats=_floats,
    lists=_lists,
    booleans=_booleans,
    sampled_from=_sampled_from,
)


def given(**strat_kwargs):
    def deco(fn):
        def runner(*args, **kwargs):
            max_examples = getattr(runner, "_pc_max_examples",
                                   _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for i in range(max_examples):
                example = {k: s.example(rng) for k, s in strat_kwargs.items()}
                try:
                    fn(*args, **example, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__name__}: "
                        f"{example!r}") from e
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        # hide the strategy-filled params so pytest doesn't see them as
        # fixtures (hypothesis does the same signature surgery)
        sig = inspect.signature(fn)
        runner.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strat_kwargs
        ])
        runner._pc_max_examples = _DEFAULT_MAX_EXAMPLES
        return runner
    return deco


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._pc_max_examples = max_examples
        return fn
    return deco
