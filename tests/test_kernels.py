"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle,
across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _mk(rng, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,Hq,Hkv,D,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),      # MHA
    (2, 256, 8, 2, 64, 64, 128),     # GQA 4:1
    (1, 512, 4, 1, 16, 128, 256),    # MQA
    (2, 128, 6, 2, 24, 32, 64),      # non-pow2 head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(rng, B, S, Hq, Hkv, D, bq, bk, dtype):
    q = _mk(rng, (B, S, Hq, D), dtype)
    k = _mk(rng, (B, S, Hkv, D), dtype)
    v = _mk(rng, (B, S, Hkv, D), dtype)
    out_ref = ops.attention(q, k, v, impl="reference")
    out_pal = ops.attention(q, k, v, impl="pallas_interpret", block_q=bq, block_kv=bk)
    np.testing.assert_allclose(
        np.asarray(out_ref, np.float32), np.asarray(out_pal, np.float32),
        atol=ATOL[dtype], rtol=1e-2,
    )


def test_flash_attention_noncausal(rng):
    q = _mk(rng, (2, 128, 4, 32))
    k = _mk(rng, (2, 128, 2, 32))
    v = _mk(rng, (2, 128, 2, 32))
    o1 = ops.attention(q, k, v, causal=False, impl="reference")
    o2 = ops.attention(q, k, v, causal=False, impl="pallas_interpret",
                       block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=1e-3)


def test_blockwise_causal_matches_exact(rng):
    q = _mk(rng, (2, 192, 4, 16))
    k = _mk(rng, (2, 192, 2, 16))
    v = _mk(rng, (2, 192, 2, 16))
    o1 = ref.attention_ref(q, k, v, causal=True)
    o2 = ref.blockwise_causal_attention(q, k, v, block_q=64, block_kv=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=1e-3)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Smax,Hq,Hkv,D,bk", [
    (2, 128, 4, 2, 32, 32),
    (1, 256, 8, 8, 64, 64),
    (3, 64, 4, 1, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(rng, B, Smax, Hq, Hkv, D, bk, dtype):
    q = _mk(rng, (B, Hq, D), dtype)
    kc = _mk(rng, (B, Smax, Hkv, D), dtype)
    vc = _mk(rng, (B, Smax, Hkv, D), dtype)
    lens = jnp.asarray(rng.integers(1, Smax, size=(B,)), jnp.int32)
    o1 = ops.decode_attention(q, kc, vc, lens, impl="reference")
    o2 = ops.decode_attention(q, kc, vc, lens, impl="pallas_interpret", block_kv=bk)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32),
        atol=ATOL[dtype], rtol=1e-2,
    )


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("Q,N,D,K", [(1, 50, 32, 4), (7, 300, 64, 8),
                                     (16, 1000, 128, 16), (3, 10, 16, 4)])
def test_topk_sim(rng, Q, N, D, K):
    q = _mk(rng, (Q, D))
    keys = _mk(rng, (N, D))
    v1, i1 = ops.topk_sim(q, keys, K, impl="reference")
    v2, i2 = ops.topk_sim(q, keys, K, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-5)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


def test_topk_sim_num_valid(rng):
    q = _mk(rng, (2, 16))
    keys = _mk(rng, (32, 16))
    padded = jnp.concatenate([keys[:20], jnp.zeros((12, 16))], axis=0)
    v1, i1 = ops.topk_sim(q, keys[:20], 5, impl="reference")
    v2, i2 = ops.topk_sim(q, padded, 5, num_valid=20, impl="reference")
    v3, i3 = ops.topk_sim(q, padded, 5, num_valid=20, impl="pallas_interpret")
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    assert np.array_equal(np.asarray(i1), np.asarray(i3))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("P,K,D", [(1, 2, 16), (10, 8, 32), (33, 16, 256)])
def test_tree_refresh(rng, P, K, D):
    emb = _mk(rng, (P, K, D))
    mask = jnp.asarray(rng.random((P, K)) > 0.4)
    # ensure at least one child each
    mask = mask.at[:, 0].set(True)
    o1 = ops.tree_refresh(emb, mask, impl="reference")
    o2 = ops.tree_refresh(emb, mask, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    # unit norm
    np.testing.assert_allclose(np.linalg.norm(np.asarray(o1), axis=-1), 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("F,K,D", [(1, 4, 64), (7, 8, 256), (64, 8, 128),
                                   (130, 16, 32)])
def test_browse_scores(rng, F, K, D):
    emb = _mk(rng, (F, K, D))
    q = _mk(rng, (F, D))
    mask = jnp.asarray((rng.random((F, K)) > 0.3).astype(np.float32))
    o1 = ops.browse_scores(emb, q, mask, impl="reference")
    o2 = ops.browse_scores(emb, q, mask, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    # oracle: per-row masked matvec
    want = np.einsum("fkd,fd->fk", np.asarray(emb), np.asarray(q)) * np.asarray(mask)
    np.testing.assert_allclose(np.asarray(o1), want, atol=2e-5)


def test_normalize_rows_matches_kernel_formula(rng):
    x = _mk(rng, (33, 64), scale=3.0)
    out = np.asarray(ops.normalize_rows(x))
    want = np.asarray(x, np.float32)
    want = want / (np.linalg.norm(want, axis=-1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(out, want, atol=1e-6)
    # pre-normalized keys + normalize=False == raw keys + normalize=True
    keys = _mk(rng, (50, 64))
    q = _mk(rng, (4, 64))
    v1, i1 = ops.topk_sim(q, keys, 5, impl="reference")
    v2, i2 = ops.topk_sim(ops.normalize_rows(q), ops.normalize_rows(keys), 5,
                          normalize=False, impl="reference")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_scatter_normalize_rows(rng):
    base = np.asarray(rng.normal(size=(16, 32)), np.float32)
    arr = ops.normalize_rows(jnp.asarray(base))
    rows = np.asarray(rng.normal(size=(4, 32)), np.float32)
    idx = np.asarray([3, 7, 16, 16], np.int32)   # two padding slots (dropped)
    out = np.asarray(ops.scatter_normalize_rows(
        arr, jnp.asarray(idx), jnp.asarray(rows)))
    want = base / (np.linalg.norm(base, axis=-1, keepdims=True) + 1e-6)
    want[3] = rows[0] / (np.linalg.norm(rows[0]) + 1e-6)
    want[7] = rows[1] / (np.linalg.norm(rows[1]) + 1e-6)
    np.testing.assert_allclose(out, want, atol=1e-6)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,H,K,V,chunk", [
    (1, 64, 2, 8, 8, 16), (2, 128, 2, 16, 16, 32), (1, 96, 3, 8, 16, 32),
])
def test_rwkv6_scan(rng, B, T, H, K, V, chunk):
    r = _mk(rng, (B, T, H, K), scale=0.5)
    k = _mk(rng, (B, T, H, K), scale=0.5)
    v = _mk(rng, (B, T, H, V), scale=0.5)
    w = _mk(rng, (B, T, H, K), scale=0.5)
    u = _mk(rng, (H, K), scale=0.5)
    s0 = _mk(rng, (B, H, K, V), scale=0.1)
    o1, s1 = ops.rwkv6_scan(r, k, v, w, u, s0, impl="reference")
    o2, s2 = ops.rwkv6_scan(r, k, v, w, u, s0, impl="pallas_interpret", chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4, rtol=1e-2)
    # chunked jnp (model path) against exact too
    o3, s3 = ref.rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=2e-4, rtol=1e-2)


def test_rwkv6_decode_step_matches_scan(rng):
    B, H, K, V = 2, 2, 8, 8
    r = _mk(rng, (B, 1, H, K)); k = _mk(rng, (B, 1, H, K))
    v = _mk(rng, (B, 1, H, V)); w = _mk(rng, (B, 1, H, K))
    u = _mk(rng, (H, K)); s0 = _mk(rng, (B, H, K, V), scale=0.1)
    o1, s1 = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    o2, s2 = ref.rwkv6_decode_step(r[:, 0], k[:, 0], v[:, 0], w[:, 0], u, s0)
    np.testing.assert_allclose(np.asarray(o1[:, 0]), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (1, 64, 2, 8, 4, 16), (2, 128, 3, 16, 8, 32),
])
def test_mamba2_ssd(rng, B, T, H, P, N, chunk):
    x = _mk(rng, (B, T, H, P))
    dt = jnp.asarray(rng.random((B, T, H)) * 0.5 + 0.01, jnp.float32)
    A = -jnp.asarray(rng.random((H,)) + 0.1, jnp.float32)
    Bm = _mk(rng, (B, T, N))
    C = _mk(rng, (B, T, N))
    s0 = _mk(rng, (B, H, P, N), scale=0.1)
    y1, s1 = ops.mamba2_ssd(x, dt, A, Bm, C, s0, impl="reference")
    y2, s2 = ops.mamba2_ssd(x, dt, A, Bm, C, s0, impl="pallas_interpret", chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4, rtol=1e-2)
    y3, s3 = ref.mamba2_ssd_chunked(x, dt, A, Bm, C, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), atol=2e-4, rtol=1e-2)
