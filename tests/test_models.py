"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finiteness; prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, cells_for_arch
from repro.models import get_model
from repro.models.factory import input_specs, make_batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, "train", 2, 32, jax.random.key(1))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    pb = make_batch(cfg, "prefill", 2, 16, jax.random.key(1))
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(params, pb)
    assert logits.shape == (2, cfg.vocab_size)
    db = make_batch(cfg, "decode", 2, 16, jax.random.key(2))
    logits2, cache2 = jax.jit(model.decode)(params, db, cache)
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ["llama3_8b", "rwkv6_1b6", "zamba2_7b"])
def test_decode_matches_prefill(arch):
    """Prefill(t[0:n]) then decode(t[n]) must equal prefill(t[0:n+1])'s last
    logits — the KV-cache/state path is consistent with the parallel path."""
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (2, 17), 0, cfg.vocab_size)

    logits_full, _ = model.prefill(params, {"tokens": toks}, 32)
    _, cache = model.prefill(params, {"tokens": toks[:, :16]}, 32)
    logits_step, _ = model.decode(params, {"tokens": toks[:, 16]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32), np.asarray(logits_step, np.float32),
        atol=5e-2, rtol=5e-2,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """Full configs' analytic param counts are in the advertised ballpark."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "whisper-base": (60e6, 120e6),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        # assigned config (81 full mamba2 layers at d_model 3584) evaluates
        # above the checkpoint's 7.4B — the vendor interleaves narrower
        # blocks; we implement the assignment as specified (DESIGN.md §5)
        "zamba2-7b": (5e9, 13e9),
        "qwen3-moe-235b-a22b": (180e9, 280e9),
        "olmoe-1b-7b": (5e9, 9e9),
        "starcoder2-7b": (6e9, 9e9),
        "phi3-mini-3.8b": (3e9, 5e9),
        "llama3-8b": (6.5e9, 9e9),
        "granite-3-8b": (6.5e9, 10e9),
        "pixtral-12b": (10e9, 14e9),
    }[cfg.name]
    assert expected[0] <= n <= expected[1], (cfg.name, f"{n:,}")
    if cfg.family == "moe":
        active = cfg.param_count(active_only=True)
        assert active < n / 4, "MoE active params should be far below total"


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_cells(arch):
    cfg = get_config(arch)
    for shape in cells_for_arch(cfg):
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        for v in specs.values():
            assert v.shape[0] == shape.global_batch
        if cfg.family == "encdec":
            assert "frames" in specs or shape.kind == "decode"
        if cfg.family == "vlm" and shape.kind != "decode":
            assert "patch_embeds" in specs
            assert specs["tokens"].shape[1] + specs["patch_embeds"].shape[1] == shape.seq_len


def test_long_500k_applicability():
    from repro.configs.shapes import shape_applicable, SHAPES
    assert shape_applicable(get_config("rwkv6_1b6"), SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("zamba2_7b"), SHAPES["long_500k"])[0]
    ok, why = shape_applicable(get_config("llama3_8b"), SHAPES["long_500k"])
    assert not ok and "full-attention" in why
