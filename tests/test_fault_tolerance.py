"""Fault tolerance control plane: heartbeats, stragglers, elastic re-mesh,
checkpoint/restart runner with injected failures."""
import jax
import jax.numpy as jnp
import pytest

from repro.runtime.fault_tolerance import (
    DEFAULT_LADDER, ElasticScaler, FaultTolerantRunner, HeartbeatMonitor,
    StragglerMitigator,
)


def test_heartbeat_detection():
    t = [0.0]
    mon = HeartbeatMonitor(["w0", "w1", "w2"], timeout_s=5.0, clock=lambda: t[0])
    t[0] = 3.0
    mon.beat("w0")
    mon.beat("w1")
    t[0] = 7.0
    failed = mon.check()
    assert failed == ["w2"]
    assert set(mon.healthy) == {"w0", "w1"}
    # failed workers stay failed even if they beat later
    mon.beat("w2")
    assert "w2" in mon.failed


def test_straggler_mitigation():
    m = StragglerMitigator(factor=3.0, min_samples=5)
    for i in range(10):
        assert m.check(i, "w0", 1.0) is None
    ev = m.check(10, "w0", 10.0)   # 10x the median
    assert ev is not None and ev.action == "backup_dispatched"
    assert len(m.events) == 1


def test_elastic_ladder():
    es = ElasticScaler()
    assert es.pick(512) == (2, 16, 16)
    assert es.pick(511) == (1, 16, 16)
    assert es.pick(128) == (1, 8, 16)
    assert es.pick(63) is None
    shape, axes = es.replan(256)
    assert shape == (16, 16) and axes == ("data", "model")


def test_runner_restart_resumes_from_checkpoint():
    """Inject a failure; the runner restores the checkpointed state AND step,
    and the final state matches an uninterrupted run (determinism)."""
    def step_fn(state, batch):
        return state + batch, {"loss": state}

    saved = {}
    def save_fn(s, step):
        saved["s"], saved["step"] = s, step
    def restore_fn():
        return saved["s"], saved["step"]

    batch_fn = lambda step: jnp.asarray(float(step))

    # uninterrupted reference
    ref = jnp.asarray(0.0)
    for step in range(0, 12):
        ref, _ = step_fn(ref, batch_fn(step))

    runner = FaultTolerantRunner(step_fn, save_fn, restore_fn, checkpoint_every=4)
    save_fn(jnp.asarray(0.0), 0)
    runner.inject_failure(7)
    state, end = FaultTolerantRunner.run(runner, jnp.asarray(0.0), 0, 12, batch_fn)
    assert end == 12
    assert runner.log.restarts == 1
    assert float(state) == float(ref)
