"""Tiered hot/cold tenant residency (core/residency.py): release_rows /
detach-reattach correctness, traffic-aware eviction under the hot budget,
rehydration parity across every browse mode, the confidence-gated digest
escalation, manager restart, and the ServeEngine / MaintenancePlane lanes."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.config import MemForestConfig
from repro.core.memforest import MemForestSystem
from repro.core.residency import (ResidencyConfig, ResidencyManager,
                                  TenantDigest)
from repro.data.synthetic import make_workload
from repro.kernels import ops

from test_query_parity import MODES, _fact_sig

ALWAYS_ESCALATE = -99.0     # any digest score clears the gate -> rehydrate
NEVER_ESCALATE = 99.0       # no score clears the gate -> digest answers


def _wl(seed, nq=8):
    return make_workload(num_entities=2, num_sessions=3,
                         transitions_per_entity=3, num_queries=nq, seed=seed)


def _mgr(tmp_path, **cfg_kw):
    cfg_kw.setdefault("hot_budget", 2)
    cfg_kw.setdefault("digest_threshold", ALWAYS_ESCALATE)
    return ResidencyManager(str(tmp_path / "tenants"),
                            config=ResidencyConfig(**cfg_kw),
                            mem_config=MemForestConfig())


# ---------------------------------------------------------------------------
# release_rows: the inverse of grow_rows
# ---------------------------------------------------------------------------
def test_release_rows_frees_and_shrinks():
    arr = jnp.asarray(np.arange(32, dtype=np.float32).reshape(8, 4))
    # keep=0: whole-buffer free
    assert ops.release_rows(arr) is None
    assert arr.is_deleted()
    # keep=n: arena shrink — fresh buffer with rows [0, n), old one freed
    arr = jnp.asarray(np.arange(32, dtype=np.float32).reshape(8, 4))
    host = np.asarray(arr)
    out = ops.release_rows(arr, keep=4)
    assert out.shape == (4, 4)
    np.testing.assert_array_equal(np.asarray(out), host[:4])
    assert arr.is_deleted() and not out.is_deleted()
    assert ops.release_rows(None) is None      # detached cache: no-op


def test_detach_reattach_roundtrip_identical():
    wl = _wl(3)
    mf = MemForestSystem(MemForestConfig())
    mf.ingest_batch(wl.sessions)
    before = [(r.answer, r.evidence) for r in mf.query_batch(wl.queries)]
    assert mf.device_bytes() > 0
    up0 = mf.forest.index_uploads

    freed = mf.detach_device()
    assert freed > 0 and mf.device_bytes() == 0
    assert mf.forest.index_releases == 2       # fact + root arenas freed

    after = [(r.answer, r.evidence) for r in mf.query_batch(wl.queries)]
    assert after == before                     # transparent reattach
    assert mf.forest.index_uploads == up0 + 2  # one fresh upload per index
    assert mf.device_bytes() > 0


# ---------------------------------------------------------------------------
# budget + eviction policy
# ---------------------------------------------------------------------------
def test_hot_budget_enforced_with_traffic_aware_victim(tmp_path):
    mgr = _mgr(tmp_path, hot_budget=2)
    wls = {t: _wl(i, nq=4) for i, t in enumerate(["a", "b", "c"])}
    mgr.ingest("a", wls["a"].sessions)
    mgr.ingest("b", wls["b"].sessions)
    # heat up "a" so "b" is the coldest resident when "c" arrives
    for _ in range(3):
        mgr.query_batch("a", wls["a"].queries[:2])
    mgr.ingest("c", wls["c"].sessions)
    m = mgr.metrics()
    assert m["hot_tenants"] <= 2 and m["evictions"] == 1
    assert mgr.is_resident("a") and mgr.is_resident("c")
    assert not mgr.is_resident("b")            # traffic-aware LRU victim
    mgr.close()


def test_device_byte_budget_triggers_demotion(tmp_path):
    mgr = _mgr(tmp_path, hot_budget=8, device_budget_bytes=1)
    mgr.ingest("a", _wl(1).sessions)
    mgr.ingest("b", _wl(2).sessions)
    # count budget allows 8 hot, the byte budget does not: only the hottest
    # tenant survives (the cap never demotes the last resident)
    assert mgr.metrics()["hot_tenants"] == 1
    assert mgr.metrics()["evictions"] >= 1
    mgr.close()


def test_evict_rehydrate_does_not_reupload_other_tenants(tmp_path):
    """Satellite regression: demoting A and rehydrating it must not touch
    B's device caches — only the rehydrated tenant's rows transfer."""
    mgr = _mgr(tmp_path, hot_budget=4)
    wla, wlb = _wl(5), _wl(6)
    mgr.ingest("a", wla.sessions)
    mgr.ingest("b", wlb.sessions)
    mgr.query_batch("a", wla.queries)          # materialize device caches
    mgr.query_batch("b", wlb.queries)
    forest_b = mgr.acquire("b").forest
    up_b = forest_b.index_uploads
    rows_b = forest_b.index_row_updates

    assert mgr.demote("a")
    assert not mgr.is_resident("a")
    mgr.query_batch("b", wlb.queries)          # B untouched by A's eviction
    assert forest_b.index_uploads == up_b
    assert forest_b.index_row_updates == rows_b

    mgr.query_batch("a", wla.queries)          # rehydrates A (escalate gate)
    forest_a = mgr.acquire("a").forest
    assert forest_a.index_uploads == 2         # exactly A's two fresh uploads
    assert forest_b.index_uploads == up_b      # and still nothing on B
    assert forest_b.index_row_updates == rows_b
    assert mgr.metrics()["rehydrations"] == 1
    mgr.close()


# ---------------------------------------------------------------------------
# rehydration parity: every browse mode, byte-identical
# ---------------------------------------------------------------------------
def test_rehydration_parity_all_modes(tmp_path):
    wl = make_workload(num_entities=4, num_sessions=6,
                       transitions_per_entity=3, num_queries=12, seed=21)
    mgr = _mgr(tmp_path, hot_budget=4)
    mgr.ingest("t", wl.sessions)
    texts = [q.text for q in wl.queries]

    store = mgr.acquire("t")
    before = {m: [( _fact_sig(f), e) for f, e, _ in
                  store.retriever.retrieve_batch(texts, mode=m)]
              for m in MODES}
    before_ans = {m: [r.answer for r in
                      mgr.query_batch("t", wl.queries, mode=m)]
                  for m in MODES}

    assert mgr.demote("t")
    assert not mgr.is_resident("t")
    # first touch rehydrates (threshold forces escalation); all six modes
    # must come back byte-identical — snapshots carry derived state, so the
    # round-trip is exact, not just semantically equivalent
    after_ans = {m: [r.answer for r in
                     mgr.query_batch("t", wl.queries, mode=m)]
                 for m in MODES}
    store2 = mgr.acquire("t")
    after = {m: [( _fact_sig(f), e) for f, e, _ in
                 store2.retriever.retrieve_batch(texts, mode=m)]
             for m in MODES}
    assert after == before
    assert after_ans == before_ans
    assert mgr.metrics()["rehydrations"] == 1
    mgr.close()


# ---------------------------------------------------------------------------
# digest escalation gate
# ---------------------------------------------------------------------------
def test_digest_answers_below_threshold_without_rehydration(tmp_path):
    wl = _wl(31)
    mgr = _mgr(tmp_path, hot_budget=2, digest_threshold=NEVER_ESCALATE)
    mgr.ingest("t", wl.sessions)
    mgr.demote("t")
    res = mgr.query_batch("t", wl.queries)
    assert len(res) == len(wl.queries)
    assert not mgr.is_resident("t")            # never paid the rehydration
    m = mgr.metrics()
    assert m["digest_answers"] == len(wl.queries) and m["rehydrations"] == 0
    # digest evidence is root-only grade: root summaries, non-empty
    assert any(r.evidence for r in res)
    mgr.close()


def test_digest_gate_escalates_above_threshold(tmp_path):
    wl = _wl(32)
    mgr = _mgr(tmp_path, hot_budget=2, digest_threshold=ALWAYS_ESCALATE)
    mgr.ingest("t", wl.sessions)
    mgr.demote("t")
    want = [r.answer for r in mgr.query_batch("t", wl.queries)]
    m = mgr.metrics()
    assert mgr.is_resident("t")                # escalated to the full store
    assert m["rehydrations"] == 1 and m["digest_answers"] == 0
    assert m["digest_escalations"] == 1
    # escalated answers are full-fidelity (match a plain system)
    ref = MemForestSystem(MemForestConfig())
    ref.ingest_batch(wl.sessions)
    assert want == [r.answer for r in ref.query_batch(wl.queries)]
    mgr.close()


def test_digest_answers_match_root_only_grade(tmp_path):
    """The digest is the root summaries — its answers must equal root-only
    browse over the same forest for queries that stay below the gate."""
    wl = _wl(33)
    mgr = _mgr(tmp_path, hot_budget=2, digest_threshold=NEVER_ESCALATE)
    mgr.ingest("t", wl.sessions)
    root_only = [r.answer for r in
                 mgr.query_batch("t", wl.queries, mode="root-only")]
    mgr.demote("t")
    digest = [r.answer for r in mgr.query_batch("t", wl.queries)]
    agree = sum(int(a == b) for a, b in zip(digest, root_only))
    assert agree >= len(wl.queries) // 2       # same evidence tier
    mgr.close()


# ---------------------------------------------------------------------------
# restart + persistence of the cold tier
# ---------------------------------------------------------------------------
def test_manager_restart_resumes_cold_tenants(tmp_path):
    wl = _wl(41)
    mgr = _mgr(tmp_path, hot_budget=2)
    mgr.ingest("t", wl.sessions, idempotency_key="t:i0")
    want_digest = mgr.state_digest("t")
    want = [r.answer for r in mgr.query_batch("t", wl.queries)]
    mgr.demote("t")
    mgr.close()

    # fresh process: tenants rediscovered COLD, digest sidecar loaded
    m2 = ResidencyManager(str(tmp_path / "tenants"),
                          config=ResidencyConfig(hot_budget=2,
                                                 digest_threshold=NEVER_ESCALATE),
                          mem_config=MemForestConfig())
    assert m2.tenant_ids() == ["t"]
    assert not m2.is_resident("t")
    assert m2.metrics()["digest_bytes"] > 0
    m2.query_batch("t", wl.queries[:2])        # served from the digest
    assert m2.metrics()["digest_answers"] == 2 and not m2.is_resident("t")
    # full rehydration is still exact
    assert m2.state_digest("t") == want_digest
    assert [r.answer for r in m2.query_batch("t", wl.queries)] == want
    m2.close()


def test_tenant_digest_roundtrip():
    emb = np.random.default_rng(0).normal(size=(3, 8)).astype(np.float32)
    d = TenantDigest(emb, ["alpha", "beta", "gamma"])
    d2 = TenantDigest.from_bytes(d.to_bytes())
    np.testing.assert_array_equal(d2.emb, emb)
    assert d2.texts == d.texts and d2.nbytes() == d.nbytes()


def test_invalid_tenant_id_rejected(tmp_path):
    mgr = _mgr(tmp_path)
    with pytest.raises(ValueError):
        mgr.acquire("..")
    with pytest.raises(ValueError):
        mgr.acquire("a/b")


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------
def test_serve_engine_multi_tenant_over_subscription(tmp_path):
    import jax

    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.serving.engine import ServeEngine

    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    mgr = _mgr(tmp_path, hot_budget=2)
    eng = ServeEngine(model, params, max_batch=4, max_len=64, residency=mgr)
    assert mgr.auto_enforce is False           # engine owns the drain

    wls = {f"t{i}": _wl(50 + i, nq=4) for i in range(5)}
    for tid, w in wls.items():
        for s in w.sessions:
            eng.submit_session(s, tenant=tid)
    # decode traffic rides alongside: eviction must not block it
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(list(rng.integers(3, 400, size=5)), max_new_tokens=3)
    done = eng.run_until_drained()
    assert len(done) == 4 and all(r.out_tokens for r in done)

    rids = {tid: [eng.submit_query(q, tenant=tid, mode="llm")
                  for q in w.queries] for tid, w in wls.items()}
    eng.run_until_drained()
    for tid, w in wls.items():
        for rid in rids[tid]:
            assert eng.pop_query_result(rid) is not None

    m = eng.metrics()
    # satellite: residency metrics ride in the engine metrics dict
    for key in ("hot_tenants", "evictions", "rehydrations", "digest_answers",
                "device_bytes", "device_bytes_est"):
        assert key in m
    assert m["hot_tenants"] <= 2               # budget drained on the plane
    assert m["evictions"] >= 3
    assert m["queries_served"] == sum(len(w.queries) for w in wls.values())
    mgr.close()


def test_maintenance_plane_drains_residency_demotions(tmp_path):
    from repro.core.maintenance_plane import MaintenancePlane

    mgr = _mgr(tmp_path, hot_budget=1)
    mgr.auto_enforce = False                   # plane owns enforcement
    mgr.ingest("a", _wl(61).sessions)
    mgr.ingest("b", _wl(62).sessions)
    assert mgr.over_budget() == 1
    plane = MaintenancePlane(mgr.acquire("b").forest, residency=mgr)
    assert plane.pending() >= 1
    plane.drain()
    assert plane.demotions_done >= 1
    assert mgr.over_budget() == 0
    assert mgr.metrics()["hot_tenants"] == 1
    mgr.close()
