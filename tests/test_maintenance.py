"""Lifecycle maintenance: delete, migration merge, re-materialization."""
import numpy as np
import pytest

from repro.config import MemForestConfig
from repro.core import maintenance
from repro.core.memforest import MemForestSystem
from repro.data.synthetic import make_workload


@pytest.fixture(scope="module")
def wl():
    return make_workload(num_entities=5, num_sessions=8,
                         transitions_per_entity=3, num_queries=20, seed=7)


def _build(sessions):
    mf = MemForestSystem(MemForestConfig())
    for s in sessions:
        mf.ingest_session(s)
    return mf


def test_delete_session_locality(wl):
    mf = _build(wl.sessions)
    sid = wl.sessions[0].session_id
    before = mf.scale_stats()
    refreshes_before = mf.forest.summary_refreshes
    stats = mf.delete_session(sid)
    after = mf.scale_stats()
    assert stats["leaves_removed"] > 0
    assert after["facts"] <= before["facts"]
    # deletion refreshed only affected paths, not the whole forest
    touched = mf.forest.summary_refreshes - refreshes_before
    assert touched < before["nodes"] * 0.5, (touched, before["nodes"])
    for t in mf.forest.trees.values():
        t.check_invariants()
    # deleted session's facts no longer retrievable
    for q in wl.queries:
        r = mf.query(q)  # must not crash on tombstones


def test_migration_merge_preserves_scale(wl):
    """Paper Table 10: merged state ~= sequentially-built state (facts within
    1%, trees within ~8%)."""
    half = len(wl.sessions) // 2
    seq = _build(wl.sessions)
    a = _build(wl.sessions[:half])
    b = _build(wl.sessions[half:])
    stats = a.merge_from(b)
    s_seq, s_mig = seq.scale_stats(), a.scale_stats()
    assert abs(s_mig["facts"] - s_seq["facts"]) <= max(1, 0.01 * s_seq["facts"])
    assert abs(s_mig["trees"] - s_seq["trees"]) <= max(2, 0.15 * s_seq["trees"])
    for t in a.forest.trees.values():
        t.check_invariants()


def test_migration_merge_answers_queries(wl):
    half = len(wl.sessions) // 2
    a = _build(wl.sessions[:half])
    b = _build(wl.sessions[half:])
    a.merge_from(b)
    seq = _build(wl.sessions)
    agree = same = 0
    for q in wl.queries:
        ra = a.query(q).answer
        rs = seq.query(q).answer
        same += int(ra == rs)
        agree += 1
    assert same >= agree * 0.8, f"merged answers diverge: {same}/{agree}"


def test_merge_copies_unmatched_trees_without_refresh(wl):
    """The migration speedup mechanism: unmatched trees are copied verbatim —
    no summary regeneration for them."""
    a = _build(wl.sessions[:2])
    b = _build(wl.sessions[2:4])
    before = a.forest.summary_refreshes
    stats = a.merge_from(b)
    touched = a.forest.summary_refreshes - before
    copied_nodes = sum(
        a.forest.trees[k].num_nodes for k in a.forest.trees
    )
    assert stats["trees_copied"] > 0
    # refreshes much smaller than total nodes (only merged trees' paths)
    assert touched < copied_nodes


def test_rematerialize_new_branching(wl):
    mf = _build(wl.sessions[:4])
    f2 = maintenance.rematerialize(mf.forest, new_branching=3)
    assert f2.scale_stats()["facts"] == mf.scale_stats()["facts"]
    for t in f2.trees.values():
        t.check_invariants()
        assert all(len(t.children[i]) <= 3 for i in range(t._n)
                   if t.alive[i] and t.level[i] > 0)


def test_migrate_merge_rerun_with_key_is_noop(wl):
    """Idempotency contract: replaying a merge under its original key (the
    journal's crash-retry case) must not change state at all."""
    from repro.core import persistence

    half = len(wl.sessions) // 2
    a = _build(wl.sessions[:half])
    b = _build(wl.sessions[half:])
    first = a.merge_from(b, idempotency_key="mig:ab")
    assert first["skipped_duplicate"] == 0
    d0 = persistence.forest_state_digest(a.forest)
    s0 = a.scale_stats()

    second = a.merge_from(b, idempotency_key="mig:ab")
    assert second["skipped_duplicate"] == 1
    assert second["facts_added"] == second["facts_merged"] == 0
    assert a.scale_stats() == s0
    assert persistence.forest_state_digest(a.forest) == d0


def test_migrate_merge_dedups_sources_and_registry(wl):
    """Provenance must stay one row per (session, chunk) / (session, fact)
    even when the same source forest merges in twice without a key —
    targeted deletion depends on it."""
    half = len(wl.sessions) // 2
    a = _build(wl.sessions[:half])
    b = _build(wl.sessions[half:])
    a.merge_from(b)
    a.merge_from(b)
    for f in a.forest.facts:
        assert len(f.sources) == len(set(map(tuple, f.sources))), f.sources
    for sid, reg in a.forest.session_registry.items():
        assert len(reg["facts"]) == len(set(reg["facts"])), sid


def test_rematerialize_does_not_alias_source_forest(wl):
    """rematerialize() returns an independent forest: mutating the copy
    (deletion zeroes fact_emb rows, edits sources and registries in place)
    must leave the source forest byte-identical."""
    from repro.core import persistence

    mf = _build(wl.sessions[:4])
    d0 = persistence.forest_state_digest(mf.forest)
    f2 = maintenance.rematerialize(mf.forest, new_branching=4)

    assert f2.fact_emb is not mf.forest.fact_emb
    assert all(c2 is not c1 for c1, c2 in zip(mf.forest.cells, f2.cells))
    assert all(g.sources is not f.sources
               for f, g in zip(mf.forest.facts, f2.facts))

    maintenance.delete_session(f2, wl.sessions[0].session_id)
    f2.fact_emb[: len(f2.facts)] = 0.0
    for c in f2.cells:
        c.text = "clobbered"
    assert persistence.forest_state_digest(mf.forest) == d0
