"""Dynamic lock-order checking (repro/analysis/lockcheck): unit coverage of
the acquisition graph, a property test over random schedules with planted
cycles, and the integration harness — engine traffic + background
maintenance + residency eviction running concurrently on instrumented
locks, asserting the observed lock graph stays acyclic."""
import os
import sys
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:           # in-repo fallback (tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.analysis.lockcheck import (BlockingCallWatch, CheckedLock,
                                      LockOrderGraph, LockOrderViolation,
                                      check_schedule, instrument)


# ---------------------------------------------------------------------------
# graph unit coverage
# ---------------------------------------------------------------------------
def test_consistent_order_is_acyclic():
    sched = []
    for t in range(4):
        sched += [(t, "acquire", "A"), (t, "acquire", "B"),
                  (t, "acquire", "C"), (t, "release", "C"),
                  (t, "release", "B"), (t, "release", "A")]
    assert check_schedule(sched) == []


def test_planted_abba_cycle_is_flagged():
    sched = [(1, "acquire", "A"), (1, "acquire", "B"),
             (1, "release", "B"), (1, "release", "A"),
             (2, "acquire", "B"), (2, "acquire", "A"),
             (2, "release", "A"), (2, "release", "B")]
    assert ["A", "B", "A"] in check_schedule(sched)


def test_three_lock_rotation_cycle():
    sched = [(1, "acquire", "A"), (1, "acquire", "B"), (1, "release", "B"),
             (1, "release", "A"),
             (2, "acquire", "B"), (2, "acquire", "C"), (2, "release", "C"),
             (2, "release", "B"),
             (3, "acquire", "C"), (3, "acquire", "A"), (3, "release", "A"),
             (3, "release", "C")]
    assert ["A", "B", "C", "A"] in check_schedule(sched)


def test_reentrant_reacquire_adds_no_edge():
    g = LockOrderGraph()
    g.on_acquire("A", thread=1)
    g.on_acquire("A", thread=1)       # RLock re-entry
    g.on_acquire("B", thread=1)
    assert ("A", "A") not in g.edges
    assert g.edges[("A", "B")] == 1
    g.on_release("B", thread=1)
    g.on_release("A", thread=1)
    g.on_release("A", thread=1)
    assert g.held_by(1) == ()


def test_assert_acyclic_raises_with_cycle_text():
    g = LockOrderGraph()
    g.on_acquire("plane", thread=1)
    g.on_acquire("residency", thread=1)
    g.on_acquire("residency", thread=2)
    g.on_acquire("plane", thread=2)
    with pytest.raises(LockOrderViolation, match="plane -> residency"):
        g.assert_acyclic()


def test_checked_lock_real_threads_opposite_order():
    """Two real threads acquiring {A, B} in opposite orders — run to
    completion sequentially so nothing deadlocks, yet the union graph holds
    the ABBA cycle: the detector does not need the fatal interleaving."""
    g = LockOrderGraph()
    A, B = CheckedLock("A", g), CheckedLock("B", g)

    def forward():
        with A:
            with B:
                pass

    def backward():
        with B:
            with A:
                pass

    for fn in (forward, backward):
        t = threading.Thread(target=fn)
        t.start()
        t.join()
    assert ["A", "B", "A"] in g.cycles()
    with pytest.raises(LockOrderViolation):
        g.assert_acyclic()


def test_blocking_call_watch_records_lock_held_fsync_and_sleep(tmp_path):
    g = LockOrderGraph()
    L = CheckedLock("L", g)
    fd = os.open(str(tmp_path / "f"), os.O_CREAT | os.O_WRONLY)
    try:
        with BlockingCallWatch(g):
            os.fsync(fd)                  # no lock held: not recorded
            with L:
                os.fsync(fd)
                time.sleep(0)
    finally:
        os.close(fd)
    assert g.blocking_calls == [(("L",), "os.fsync"), (("L",), "time.sleep")]
    # patching is undone on exit
    with L:
        time.sleep(0)
    assert len(g.blocking_calls) == 2


def test_instrument_swaps_component_lock():
    class Component:
        def __init__(self):
            self.lock = threading.RLock()

    g = LockOrderGraph()
    c = Component()
    wrapped = instrument(c, g, "component")
    assert c.lock is wrapped
    with c.lock:
        assert g.held_by() == ("component",)
    with pytest.raises(AttributeError):
        instrument(object(), g, "x")


# ---------------------------------------------------------------------------
# property test: random schedules, planted cycle always flagged,
# cycle-free never flagged
# ---------------------------------------------------------------------------
def _ordered_schedule(rng_picks, n_threads, n_locks):
    """Cycle-free by construction: every thread acquires its lock subset in
    ascending global order (and releases in reverse)."""
    names = [f"L{i}" for i in range(n_locks)]
    sched = []
    for t in range(n_threads):
        subset = sorted({names[p % n_locks]
                         for p in rng_picks[t::max(n_threads, 1)]})
        sched += [(t, "acquire", n) for n in subset]
        sched += [(t, "release", n) for n in reversed(subset)]
    return sched


@settings(max_examples=60)
@given(picks=st.lists(st.integers(min_value=0, max_value=23),
                      min_size=2, max_size=24),
       n_threads=st.integers(min_value=1, max_value=4),
       n_locks=st.integers(min_value=2, max_value=6),
       plant=st.booleans())
def test_random_schedules_flag_exactly_planted_cycles(picks, n_threads,
                                                      n_locks, plant):
    sched = _ordered_schedule(picks, n_threads, n_locks)
    if plant:
        # one rogue pair of simulated threads acquiring in opposite orders
        a, b = "L0", f"L{n_locks - 1}"
        sched += [("rogue1", "acquire", a), ("rogue1", "acquire", b),
                  ("rogue1", "release", b), ("rogue1", "release", a),
                  ("rogue2", "acquire", b), ("rogue2", "acquire", a),
                  ("rogue2", "release", a), ("rogue2", "release", b)]
    cycles = check_schedule(sched)
    if plant:
        # a cycle is always detected (DFS back edge); the exact cycle
        # reported may route through ordered edges, but every hop of every
        # reported cycle must be a real observed acquisition edge
        assert cycles, "planted ABBA cycle missed"
        g = LockOrderGraph()
        for t, op, n in sched:
            (g.on_acquire if op == "acquire" else g.on_release)(n, thread=t)
        for cyc in cycles:
            for x, y in zip(cyc, cyc[1:]):
                assert (x, y) in g.edges, f"phantom edge {x}->{y} in {cyc}"
    else:
        assert cycles == [], f"false positive on ordered schedule: {cycles}"


# ---------------------------------------------------------------------------
# integration: the real serve stack under concurrent load
# ---------------------------------------------------------------------------
def test_serve_stack_lock_graph_is_acyclic_under_concurrent_load(tmp_path):
    """Engine traffic on the caller thread, the maintenance plane's
    background worker, and direct residency evictions from a third thread —
    all on instrumented locks. The plane acquires plane -> residency (its
    worker runs enforce_budget while holding its own lock); nothing may
    ever acquire them in the other order. Also pins the one sanctioned
    lock-held blocking call: demotion fsyncs under the residency lock."""
    import jax

    from repro.config import MemForestConfig
    from repro.configs import get_smoke_config
    from repro.core.maintenance_plane import MaintenancePlane
    from repro.core.residency import ResidencyConfig, ResidencyManager
    from repro.data.synthetic import make_workload
    from repro.models import get_model
    from repro.serving.engine import ServeEngine

    wl = make_workload(num_entities=4, num_sessions=8,
                       transitions_per_entity=2, num_queries=6, seed=23)
    mgr = ResidencyManager(str(tmp_path / "tenants"),
                           config=ResidencyConfig(hot_budget=2),
                           mem_config=MemForestConfig())
    mgr.ingest("t0", wl.sessions[:2], idempotency_key="seed")
    plane = MaintenancePlane(mgr.acquire("t0").forest,
                             flush_trees_per_unit=2, residency=mgr)

    g = LockOrderGraph()
    instrument(plane, g, "plane")
    instrument(mgr, g, "residency")

    cfg = get_smoke_config("llama3_8b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=2, max_len=32,
                      residency=mgr, maintenance=plane,
                      maintenance_budget=2)

    sys.setswitchinterval(1e-5)       # force frequent thread switches
    try:
        with BlockingCallWatch(g):
            plane.start_background(interval_s=0.001, budget_per_wake=2)
            stop = threading.Event()

            def evictor():
                i = 0
                while not stop.is_set():
                    mgr.ingest(f"ev{i % 3}", [wl.sessions[i % len(wl.sessions)]],
                               idempotency_key=f"ev:{i}")
                    mgr.enforce_budget(4)
                    i += 1

            ev = threading.Thread(target=evictor)
            ev.start()
            try:
                for s in wl.sessions:
                    eng.submit_session(s, tenant="t0")
                rids = [eng.submit_query(q, tenant="t0") for q in wl.queries]
                eng.run_until_drained()
                for r in rids:
                    eng.pop_query_result(r)
            finally:
                stop.set()
                ev.join()
                plane.stop_background(drain_first=True)
    finally:
        sys.setswitchinterval(0.005)
    mgr.close()

    # both locks were actually exercised across threads
    held_names = {n for e in g.edges for n in e} | \
        {n for held, _ in g.blocking_calls for n in held}
    assert "residency" in held_names

    g.assert_acyclic()
    assert ("residency", "plane") not in g.edges

    # blocking calls under instrumented locks are exactly the sanctioned
    # set: demotion/digest fsync + checkpoint writes under residency (or
    # plane->residency), never an unexplained sleep under a lock
    allowed = {(("residency",), "os.fsync"),
               (("plane", "residency"), "os.fsync")}
    assert set(g.blocking_calls) <= allowed, set(g.blocking_calls) - allowed


def test_inverted_acquisition_fixture_is_detected():
    """A deliberately wrong component that takes residency THEN plane while
    the plane's own path takes plane THEN residency — the harness must
    flag it even though the run never deadlocks."""
    g = LockOrderGraph()
    plane_lock = CheckedLock("plane", g)
    residency_lock = CheckedLock("residency", g)

    def plane_worker():               # the stack's real order
        for _ in range(5):
            with plane_lock:
                with residency_lock:
                    pass

    def buggy_evictor():              # inverted: residency -> plane
        for _ in range(5):
            with residency_lock:
                with plane_lock:
                    pass

    t1 = threading.Thread(target=plane_worker)
    t1.start()
    t1.join()
    t2 = threading.Thread(target=buggy_evictor)
    t2.start()
    t2.join()

    with pytest.raises(LockOrderViolation, match="plane -> residency"):
        g.assert_acyclic()
    assert ["plane", "residency", "plane"] in g.cycles()
