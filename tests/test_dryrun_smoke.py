"""Dry-run smoke: the full lower+compile+analyse path on reduced configs and
a tiny virtual mesh, in a subprocess (so the 8 virtual devices never leak
into this pytest process, which must see 1 device)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT,
    )


@pytest.mark.parametrize("arch", ["llama3_8b", "qwen3_moe_235b", "rwkv6_1b6",
                                  "zamba2_7b", "whisper_base"])
def test_smoke_dryrun_single_mesh(arch, tmp_path):
    r = _run_dryrun(["--smoke", "--arch", arch, "--shape", "train_4k",
                     "--mesh", "single", "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    with open(tmp_path / f"{arch}__train_4k__single.json") as fh:
        data = json.load(fh)
    assert data["ok"]
    assert data["roofline"]["hlo_flops_total"] > 0


def test_smoke_dryrun_multipod_decode(tmp_path):
    r = _run_dryrun(["--smoke", "--arch", "llama3_8b", "--shape", "decode_32k",
                     "--mesh", "multi", "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    with open(tmp_path / f"llama3_8b__decode_32k__multi.json") as fh:
        data = json.load(fh)
    assert data["ok"]
    assert data["mesh_shape"] == [2, 2, 2]


def test_device_count_isolation():
    """This process must see exactly ONE device (XLA_FLAGS only in dryrun)."""
    import jax
    assert len(jax.devices()) == 1


def test_hlo_collective_parser_units():
    from repro.launch.hlo_analysis import _shape_bytes, collective_bytes
    assert _shape_bytes("f32[64,128]") == 64 * 128 * 4
    assert _shape_bytes("bf16[2,4] f32[8]") == 16 + 32
    hlo = """
cond_c (p: (s32[])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%x, %c), direction=LT
}
body_c (p: (s32[])) -> (s32[]) {
  %ar = f32[100]{0} all-reduce(%y), replica_groups=[1,4]<=[4]
}
ENTRY main (p: f32[100]) -> f32[100] {
  %w = (s32[]) while(%t), condition=%cond_c, body=%body_c
  %ag = f32[200]{0} all-gather(%p), replica_groups=[1,4]<=[4]
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 5 * 400      # trip-count expanded
    assert out["all-gather"] == 800
    assert out["total"] == 5 * 400 + 800
